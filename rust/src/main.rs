//! `gcore` — the G-Core reproduction launcher.
//!
//! Subcommands:
//!   train              run RLHF training (config file or flags)
//!   bench <e1..e9|all> regenerate an experiment table (DESIGN.md §4)
//!   simulate           run a placement simulation (colocate/coexist/dynamic)
//!   inspect-artifacts  print the manifest of an artifact set
//!   help

use anyhow::{bail, Result};

use gcore::config::RunConfig;
use gcore::experiments;
use gcore::launch;
use gcore::placement::{run_coexist_static, run_colocate, run_dynamic, PlacementSpec};
use gcore::runtime::Manifest;
use gcore::util::cli::Args;

const USAGE: &str = "\
gcore — G-Core RLHF trainer (reproduction)

USAGE:
  gcore train [--config <file.json>] [--artifacts tiny] [--world N]
              [--steps N] [--reward ground_truth|bt|generative]
              [--dynamic-sampling] [--checkpoint-dir DIR]
  gcore bench <e1|e2|e3|e4|e5|e7|e8|e9|all> [--full]
  gcore simulate [--placement colocate|coexist|dynamic] [--devices N]
                 [--steps N] [--dapo]
  gcore inspect-artifacts [--artifacts tiny]
";

fn main() -> Result<()> {
    let args = Args::parse_env();
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("bench") => cmd_bench(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("inspect-artifacts") => cmd_inspect(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts = a.to_string();
    }
    cfg.world = args.parse_or("world", cfg.world);
    cfg.steps = args.parse_or("steps", cfg.steps);
    cfg.sft_steps = args.parse_or("sft-steps", cfg.sft_steps);
    cfg.group_size = args.parse_or("group-size", cfg.group_size);
    cfg.lr = args.parse_or("lr", cfg.lr);
    cfg.seed = args.parse_or("seed", cfg.seed);
    if args.has("dynamic-sampling") {
        cfg.dynamic_sampling = true;
    }
    if let Some(r) = args.get("reward") {
        cfg.reward = match r {
            "ground_truth" => gcore::reward::RewardKind::GroundTruth,
            "bt" | "bradley_terry" => gcore::reward::RewardKind::BradleyTerry,
            "generative" | "genrm" => gcore::reward::RewardKind::Generative,
            other => bail!("unknown reward '{other}'"),
        };
    }
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.to_string());
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = 10;
        }
    }
    cfg.validate()?;

    println!(
        "[gcore] training: artifacts={} world={} steps={} reward={:?} dapo={}",
        cfg.artifacts, cfg.world, cfg.steps, cfg.reward, cfg.dynamic_sampling
    );
    let report = launch::run_training(&cfg)?;
    println!("\nstep | loss | kl | reward | accuracy | gen_len | rounds");
    println!("-----|------|----|--------|----------|---------|-------");
    for s in &report.steps {
        println!(
            "{:>4} | {:>6.4} | {:>6.4} | {:>5.3} | {:>5.3} | {:>6.1} | {:>4.1}",
            s.step, s.loss, s.kl, s.mean_reward, s.accuracy, s.mean_gen_len, s.gen_rounds
        );
    }
    println!(
        "\neval accuracy: before RLHF {:.3} → after {:.3}",
        report.eval_before, report.eval_after
    );
    println!("\nstage timers:\n{}", report.timers_markdown);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = !args.has("full");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        vec!["e1", "e2", "e3", "e4", "e5", "e7", "e8", "e9"]
    } else {
        vec![which]
    };
    for id in ids {
        if experiments::run(id, quick).is_none() {
            bail!("unknown experiment '{id}' (e6/e10 are examples: genrm_vs_bt, rlhf_e2e)");
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut spec = PlacementSpec::paper_like();
    spec.n_devices = args.parse_or("devices", spec.n_devices);
    spec.steps = args.parse_or("steps", spec.steps);
    spec.batch = args.parse_or("batch", spec.batch);
    spec.dynamic_sampling = args.has("dapo");
    if spec.dynamic_sampling {
        spec.accept.p0 = 0.5;
    }
    let placement = args.get_or("placement", "dynamic");
    let report = match placement {
        "colocate" => run_colocate(&spec),
        "coexist" => run_coexist_static(&spec, args.parse_or("gen-frac", 0.5)),
        "dynamic" => {
            let d = run_dynamic(&spec);
            println!("ratio trace (step, gen_frac, util_gen, util_reward):");
            for (s, fr, ug, ur) in d.trace.iter().step_by((d.trace.len() / 12).max(1)) {
                println!("  {s:>4}  {fr:.3}  {ug:.3}  {ur:.3}");
            }
            d.report
        }
        other => bail!("unknown placement '{other}'"),
    };
    println!(
        "\n{placement}: makespan {:.0}s  util {:.1}%  swap {:.0} dev-s  bubble {:.0} dev-s  ({:.0} samples/h)",
        report.makespan_s,
        report.utilization * 100.0,
        report.swap_s,
        report.bubble_s,
        report.samples_per_hour()
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let name = args.get_or("artifacts", "tiny");
    let manifest = Manifest::load(gcore::runtime::artifacts_dir(name))?;
    let d = &manifest.dims;
    println!(
        "artifact set '{}': {:.2}M params (policy), {:.2}M (scalar), pallas={}",
        d.name,
        manifest.param_count as f64 / 1e6,
        manifest.scalar_param_count as f64 / 1e6,
        d.use_pallas
    );
    println!(
        "dims: vocab={} d_model={} layers={} heads={} seq={} prompt={} batch={}",
        d.vocab, d.d_model, d.n_layers, d.n_heads, d.max_seq, d.prompt_len, d.batch
    );
    println!("\n| artifact | inputs | outputs | HLO KB |");
    println!("|---|---|---|---|");
    for (name, a) in &manifest.artifacts {
        println!(
            "| {name} | {} | {} | {} |",
            a.inputs.len(),
            a.outputs.len(),
            a.hlo_bytes / 1024
        );
    }
    Ok(())
}
