//! Multimodal payload generator (paper §3.1's controller-bottleneck
//! arithmetic).
//!
//! The paper's failure case: "a rollout of 1024 samples, each containing
//! 32 2k-resolution images, would already occupy at least 768 GB" on a
//! single controller.  These synthetic image tensors have exactly the
//! byte footprint of that scenario, so moving them through a controller's
//! data plane measures the real memory/bandwidth behaviour (E1) without
//! needing real images.

use crate::util::rng::Rng;

/// One sample's multimodal attachment set.
#[derive(Debug, Clone)]
pub struct Payload {
    pub sample_id: u64,
    /// raw image buffers (H×W×3 u8 each)
    pub images: Vec<Vec<u8>>,
}

impl Payload {
    pub fn size_bytes(&self) -> usize {
        self.images.iter().map(|i| i.len()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct PayloadSpec {
    pub images_per_sample: usize,
    pub width: usize,
    pub height: usize,
}

impl PayloadSpec {
    /// The paper's scenario: 32 images at 2k resolution.
    pub fn paper_2k() -> PayloadSpec {
        PayloadSpec { images_per_sample: 32, width: 2048, height: 2048 }
    }

    /// Scaled-down spec for in-process benches.
    pub fn scaled(&self, factor: usize) -> PayloadSpec {
        PayloadSpec {
            images_per_sample: self.images_per_sample,
            width: self.width / factor,
            height: self.height / factor,
        }
    }

    pub fn bytes_per_image(&self) -> usize {
        self.width * self.height * 3
    }

    pub fn bytes_per_sample(&self) -> usize {
        self.images_per_sample * self.bytes_per_image()
    }

    /// The §3.1 headline check: bytes for a whole rollout.
    pub fn rollout_bytes(&self, samples: usize) -> usize {
        samples * self.bytes_per_sample()
    }

    /// Generate a sample's payload.  Buffers are filled with a cheap
    /// pattern (not zeros — defeats page dedup / lazy allocation).
    pub fn generate(&self, sample_id: u64, rng: &mut Rng) -> Payload {
        let images = (0..self.images_per_sample)
            .map(|_| {
                let n = self.bytes_per_image();
                let seed = rng.next_u64();
                let mut buf = vec![0u8; n];
                // fill every 4KB page with a distinct byte
                for (i, chunk) in buf.chunks_mut(4096).enumerate() {
                    let b = ((seed as usize).wrapping_add(i) % 255) as u8 + 1;
                    chunk.fill(b);
                }
                buf
            })
            .collect();
        Payload { sample_id, images }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_arithmetic_reproduced() {
        // 1024 samples × 32 images × 2k² × 3 bytes ≥ 768 GB — the §3.1 claim
        // (the paper counts ~2 bytes/px for decoded tensors; raw u8 RGB is 3)
        let spec = PayloadSpec::paper_2k();
        let total = spec.rollout_bytes(1024);
        assert!(
            total as f64 >= 384.0 * 1e9,
            "rollout bytes {total} must exceed hundreds of GB"
        );
        // per-sample: 32 × 12.6 MB ≈ 400 MB
        assert!(spec.bytes_per_sample() > 300 * 1024 * 1024);
    }

    #[test]
    fn generate_allocates_real_bytes() {
        let spec = PayloadSpec::paper_2k().scaled(16); // 128×128
        let mut rng = Rng::new(1);
        let p = spec.generate(7, &mut rng);
        assert_eq!(p.images.len(), 32);
        assert_eq!(p.size_bytes(), spec.bytes_per_sample());
        // non-zero content
        assert!(p.images[0].iter().any(|&b| b != 0));
    }

    #[test]
    fn scaled_reduces_quadratically() {
        let spec = PayloadSpec::paper_2k();
        let s4 = spec.scaled(4);
        assert_eq!(s4.bytes_per_image() * 16, spec.bytes_per_image());
    }
}
