//! Synthetic data substrate: byte tokenizer, ground-truth reasoning tasks,
//! preference pairs, verifier SFT data, and multimodal payloads.
//! See DESIGN.md §1 for the paper-data → synthetic-data substitution.

pub mod payload;
pub mod tasks;
pub mod tokenizer;

pub use payload::{Payload, PayloadSpec};
pub use tasks::{preference_pair, verifier_example, verifier_query, PreferencePair, Task, TaskGen, TaskKind};
