//! Byte-level tokenizer: vocab = 256 raw bytes.
//!
//! Simple by design — the models are byte-level transformers, so encode/
//! decode are identity maps with padding helpers.  Token 0 (NUL) doubles
//! as padding; '\n' (10) is the end-of-response marker the sampler stops
//! on and the reward extractors split on.

pub const VOCAB: usize = 256;
pub const PAD: i32 = 0;
pub const EOS: i32 = b'\n' as i32;

pub fn encode(s: &str) -> Vec<i32> {
    s.bytes().map(|b| b as i32).collect()
}

pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t) && t != PAD)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).to_string()
}

/// Left-pad with spaces to exactly `width` bytes (the fixed-prompt-length
/// contract the prefill artifact bakes in).  Errors if the text is longer.
pub fn pad_prompt(s: &str, width: usize) -> anyhow::Result<Vec<i32>> {
    let toks = encode(s);
    if toks.len() > width {
        anyhow::bail!("prompt '{s}' is {} bytes > prompt_len {width}", toks.len());
    }
    let mut out = vec![b' ' as i32; width - toks.len()];
    out.extend(toks);
    Ok(out)
}

/// The response part of a generated row: tokens after the prompt, cut at
/// the first EOS (exclusive).
pub fn extract_response(row: &[i32], prompt_len: usize) -> String {
    let gen = &row[prompt_len.min(row.len())..];
    let end = gen.iter().position(|&t| t == EOS).unwrap_or(gen.len());
    decode(&gen[..end])
}

/// Index of the last meaningful token of a row (EOS if present) — the
/// position the BT reward head scores.
pub fn last_token_index(row: &[i32], prompt_len: usize) -> usize {
    let gen = &row[prompt_len.min(row.len())..];
    match gen.iter().position(|&t| t == EOS) {
        Some(i) => prompt_len + i,
        None => row.len() - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "12+34=46\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn pad_prompt_left_aligns() {
        let p = pad_prompt("3+4=", 8).unwrap();
        assert_eq!(p.len(), 8);
        assert_eq!(decode(&p), "    3+4=");
        assert!(pad_prompt("very long prompt", 8).is_err());
    }

    #[test]
    fn extract_response_stops_at_eos() {
        let mut row = pad_prompt("3+4=", 8).unwrap();
        row.extend(encode("7\njunk"));
        assert_eq!(extract_response(&row, 8), "7");
        assert_eq!(last_token_index(&row, 8), 9); // the EOS position
    }

    #[test]
    fn no_eos_takes_whole_tail() {
        let mut row = pad_prompt("q=", 4).unwrap();
        row.extend(encode("123"));
        assert_eq!(extract_response(&row, 4), "123");
        assert_eq!(last_token_index(&row, 4), row.len() - 1);
    }

    #[test]
    fn decode_skips_padding() {
        assert_eq!(decode(&[PAD, 65, PAD, 66]), "AB");
    }
}
