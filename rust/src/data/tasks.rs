//! Synthetic reasoning tasks with programmatic ground truth (paper §5
//! substitution — see DESIGN.md §1).
//!
//! Ground-truth-checkable tasks are what the generative-verifier line of
//! work evaluates on; they give the RLHF loop a *real* reward signal while
//! staying tractable for byte-level models: single-digit arithmetic,
//! max-of-two, copy and reverse.  Each task yields
//!   * an RL prompt (fixed width, left-padded),
//!   * demonstration strings for SFT warm-start,
//!   * preference pairs for Bradley-Terry reward training,
//!   * labeled verification strings for generative-verifier SFT.

use crate::data::tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// "a+b=" with single-digit a,b
    Add,
    /// "max a b="
    Max,
    /// "copy xyz=" → "xyz"
    Copy,
    /// "rev xyz=" → "zyx"
    Rev,
}

impl TaskKind {
    pub fn all() -> [TaskKind; 4] {
        [TaskKind::Add, TaskKind::Max, TaskKind::Copy, TaskKind::Rev]
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Add => "add",
            TaskKind::Max => "max",
            TaskKind::Copy => "copy",
            TaskKind::Rev => "rev",
        }
    }
}

/// One task instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    pub kind: TaskKind,
    pub prompt: String,
    pub answer: String,
}

impl Task {
    pub fn check(&self, response: &str) -> bool {
        response.trim() == self.answer
    }

    /// Fixed-width token prompt (the prefill contract).
    pub fn prompt_tokens(&self, width: usize) -> anyhow::Result<Vec<i32>> {
        tokenizer::pad_prompt(&self.prompt, width)
    }

    /// Full demonstration row "prompt + answer\n" padded to `seq` tokens —
    /// SFT warm-start data.  Returns (tokens, loss_mask) where the mask
    /// covers only the answer span (+EOS).
    pub fn demonstration(&self, prompt_width: usize, seq: usize) -> anyhow::Result<(Vec<i32>, Vec<f32>)> {
        let mut row = self.prompt_tokens(prompt_width)?;
        let answer = tokenizer::encode(&format!("{}\n", self.answer));
        if row.len() + answer.len() > seq {
            anyhow::bail!("demonstration longer than seq {seq}");
        }
        let answer_start = row.len();
        row.extend(&answer);
        let answer_end = row.len();
        row.resize(seq, tokenizer::PAD);
        let mut mask = vec![0.0; seq];
        for m in mask.iter_mut().take(answer_end).skip(answer_start) {
            *m = 1.0;
        }
        Ok((row, mask))
    }
}

/// Seeded task generator.
#[derive(Debug, Clone)]
pub struct TaskGen {
    pub kinds: Vec<TaskKind>,
    rng: Rng,
}

impl TaskGen {
    pub fn new(kinds: Vec<TaskKind>, seed: u64) -> TaskGen {
        assert!(!kinds.is_empty());
        TaskGen { kinds, rng: Rng::new(seed) }
    }

    pub fn sample(&mut self) -> Task {
        let kind = self.kinds[self.rng.below(self.kinds.len())];
        match kind {
            TaskKind::Add => {
                let a = self.rng.below(10);
                let b = self.rng.below(10);
                Task {
                    kind,
                    prompt: format!("{a}+{b}="),
                    answer: format!("{}", a + b),
                }
            }
            TaskKind::Max => {
                let a = self.rng.below(10);
                let b = self.rng.below(10);
                Task {
                    kind,
                    prompt: format!("max {a} {b}="),
                    answer: format!("{}", a.max(b)),
                }
            }
            TaskKind::Copy => {
                let s = self.rand_word(3);
                Task { kind, prompt: format!("copy {s}="), answer: s }
            }
            TaskKind::Rev => {
                let s = self.rand_word(3);
                Task {
                    kind,
                    prompt: format!("rev {s}="),
                    answer: s.chars().rev().collect(),
                }
            }
        }
    }

    pub fn sample_n(&mut self, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.sample()).collect()
    }

    /// Snapshot the generator's RNG mid-stream (checkpoint-resume: the
    /// restored generator continues with exactly the next task the
    /// original would have produced).
    pub fn rng_state(&self) -> crate::util::rng::RngState {
        self.rng.state()
    }

    /// Restore a mid-stream RNG snapshot taken with [`rng_state`].
    ///
    /// [`rng_state`]: TaskGen::rng_state
    pub fn restore_rng(&mut self, state: crate::util::rng::RngState) {
        self.rng = Rng::from_state(state);
    }

    fn rand_word(&mut self, len: usize) -> String {
        (0..len)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }

    /// A plausible-but-wrong answer (for preference pairs / verifier SFT).
    pub fn corrupt(&mut self, task: &Task) -> String {
        match task.kind {
            TaskKind::Add | TaskKind::Max => {
                let v: i64 = task.answer.parse().unwrap_or(0);
                let delta = 1 + self.rng.below(3) as i64;
                let sign = if self.rng.bool(0.5) { 1 } else { -1 };
                let mut c = v + sign * delta;
                if c < 0 || c == v {
                    c = v + delta; // guarantee a different, non-negative value
                }
                format!("{c}")
            }
            TaskKind::Copy | TaskKind::Rev => {
                // corruption mix calibrated for learnable-but-imperfect
                // reward models at tiny scale (DESIGN.md §1): 70% length
                // corruptions (detectable from positional structure), 30%
                // adjacent swaps (require content comparison — hard)
                let mut chars: Vec<char> = task.answer.chars().collect();
                if self.rng.bool(0.7) || chars.len() < 2 {
                    if self.rng.bool(0.5) {
                        chars.push((b'a' + self.rng.below(26) as u8) as char);
                    } else if chars.len() >= 2 {
                        chars.pop();
                    } else {
                        chars.push('x');
                    }
                } else {
                    let i = self.rng.below(chars.len() - 1);
                    chars.swap(i, i + 1);
                    if chars.iter().collect::<String>() == task.answer {
                        chars[0] = if chars[0] == 'z' { 'a' } else { 'z' };
                    }
                }
                chars.into_iter().collect()
            }
        }
    }
}

/// A Bradley-Terry preference pair: same prompt, correct vs corrupted
/// answer, as full padded rows + last-token indices.
#[derive(Debug, Clone)]
pub struct PreferencePair {
    pub chosen: Vec<i32>,
    pub rejected: Vec<i32>,
    pub chosen_idx: usize,
    pub rejected_idx: usize,
}

pub fn preference_pair(
    gen: &mut TaskGen,
    prompt_width: usize,
    seq: usize,
) -> anyhow::Result<PreferencePair> {
    let task = gen.sample();
    let wrong = gen.corrupt(&task);
    let mk = |answer: &str| -> anyhow::Result<(Vec<i32>, usize)> {
        let mut row = task.prompt_tokens(prompt_width)?;
        row.extend(tokenizer::encode(&format!("{answer}\n")));
        if row.len() > seq {
            anyhow::bail!("row longer than seq");
        }
        let idx = row.len() - 1; // the EOS position
        row.resize(seq, tokenizer::PAD);
        Ok((row, idx))
    };
    let (chosen, chosen_idx) = mk(&task.answer)?;
    let (rejected, rejected_idx) = mk(&wrong)?;
    Ok(PreferencePair { chosen, rejected, chosen_idx, rejected_idx })
}

/// Verifier SFT sample: "<prompt><answer> V:yes|no\n" with the loss mask on
/// the verdict tokens — the generative-reward training data (paper §3.2).
pub fn verifier_example(
    gen: &mut TaskGen,
    prompt_width: usize,
    seq: usize,
) -> anyhow::Result<(Vec<i32>, Vec<f32>, bool)> {
    let task = gen.sample();
    let correct = gen.rng_bool();
    let answer = if correct { task.answer.clone() } else { gen.corrupt(&task) };
    let verdict = if correct { "yes" } else { "no" };
    let mut row = task.prompt_tokens(prompt_width)?;
    row.extend(tokenizer::encode(&format!("{answer} V:")));
    let verdict_start = row.len();
    row.extend(tokenizer::encode(&format!("{verdict}\n")));
    let verdict_end = row.len();
    if row.len() > seq {
        anyhow::bail!("verifier row longer than seq");
    }
    row.resize(seq, tokenizer::PAD);
    let mut mask = vec![0.0; seq];
    for m in mask.iter_mut().take(verdict_end).skip(verdict_start) {
        *m = 1.0;
    }
    Ok((row, mask, correct))
}

/// The verifier *query* for a candidate answer at reward time.
pub fn verifier_query(task: &Task, answer: &str, prompt_width: usize) -> String {
    // same surface form as verifier_example builds, up to "V:"
    let padded: String = {
        let pad = prompt_width.saturating_sub(task.prompt.len());
        format!("{}{}", " ".repeat(pad), task.prompt)
    };
    format!("{padded}{answer} V:")
}

impl TaskGen {
    fn rng_bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_are_self_consistent() {
        let mut g = TaskGen::new(TaskKind::all().to_vec(), 1);
        for _ in 0..200 {
            let t = g.sample();
            assert!(t.check(&t.answer), "{t:?}");
            let wrong = g.corrupt(&t);
            assert!(!t.check(&wrong), "corrupt must be wrong: {t:?} vs {wrong}");
        }
    }

    #[test]
    fn prompts_fit_fixed_width() {
        let mut g = TaskGen::new(TaskKind::all().to_vec(), 2);
        for _ in 0..200 {
            let t = g.sample();
            let p = t.prompt_tokens(16).unwrap();
            assert_eq!(p.len(), 16);
        }
    }

    #[test]
    fn demonstration_mask_covers_answer_only() {
        let mut g = TaskGen::new(vec![TaskKind::Add], 3);
        let t = g.sample();
        let (row, mask) = t.demonstration(16, 64).unwrap();
        assert_eq!(row.len(), 64);
        assert_eq!(mask.len(), 64);
        // prompt region unmasked
        assert!(mask[..16].iter().all(|&m| m == 0.0));
        let masked: usize = mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(masked, t.answer.len() + 1); // answer + EOS
        // decoded row contains the answer
        let resp = tokenizer::extract_response(&row, 16);
        assert_eq!(resp, t.answer);
    }

    #[test]
    fn preference_pairs_differ_only_in_answer() {
        let mut g = TaskGen::new(vec![TaskKind::Add, TaskKind::Max], 4);
        let p = preference_pair(&mut g, 16, 64).unwrap();
        assert_eq!(p.chosen[..16], p.rejected[..16]); // same prompt
        assert_ne!(p.chosen, p.rejected);
        assert_eq!(p.chosen[p.chosen_idx], tokenizer::EOS);
        assert_eq!(p.rejected[p.rejected_idx], tokenizer::EOS);
    }

    #[test]
    fn verifier_examples_labelled_consistently() {
        let mut g = TaskGen::new(TaskKind::all().to_vec(), 5);
        let mut yes = 0;
        let mut no = 0;
        for _ in 0..100 {
            let (row, mask, correct) = verifier_example(&mut g, 16, 64).unwrap();
            let text = tokenizer::decode(&row);
            if correct {
                yes += 1;
                assert!(text.contains("V:yes"), "{text}");
            } else {
                no += 1;
                assert!(text.contains("V:no"), "{text}");
            }
            assert!(mask.iter().any(|&m| m == 1.0));
        }
        assert!(yes > 20 && no > 20, "labels should be balanced: {yes}/{no}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<Task> = TaskGen::new(vec![TaskKind::Add], 7).sample_n(10);
        let b: Vec<Task> = TaskGen::new(vec![TaskKind::Add], 7).sample_n(10);
        assert_eq!(a, b);
    }
}
