//! Checkpointing (paper §4.3): asynchronous, on-demand with a deadline,
//! distributed (per-shard), elastic across cluster sizes.
//!
//! * **Async** — `save_async` snapshots state in-memory and writes on a
//!   background thread; training continues immediately.
//! * **On-demand with deadline** — when online services reclaim resources,
//!   `save_with_deadline` attempts a checkpoint but abandons it (removing
//!   the partial file) if the deadline passes: "If the checkpoint cannot be
//!   completed within the specified time, we abandon the current progress
//!   and release resources."
//! * **Distributed / elastic** — each controller writes its own shard file;
//!   the dataloader state is global (storage::dataloader), so a checkpoint
//!   taken at world size W resumes at any divisor world size.
//!
//! Layout: `<dir>/step_<N>/meta.json` + `shard_<r>.bin` (+ `.tmp` during
//! write; atomic rename on completion — a crash never corrupts the latest
//! complete checkpoint).

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;
use crate::storage::dataloader::LoaderState;
use crate::util::codec::{Reader, Writer};
use crate::util::json::Json;
use crate::util::rng::RngState;

/// Everything one controller shard persists.
///
/// Besides the named parameter sets, a shard carries the exact RNG stream
/// positions of its controller (sampling RNG + task generator) and the
/// optimizer step count.  Those are what make crash-restart resume
/// **bit-identical** to an uninterrupted run: a resumed rank picks up the
/// random streams mid-sentence instead of replaying them from the seed.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardState {
    pub rank: usize,
    /// named parameter sets: policy, ref, reward, adam m/v, ...
    pub params: Vec<(String, ParamSet)>,
    pub rng_seed: u64,
    /// optimizer step count at the checkpoint boundary (`TrainState.step`)
    pub opt_step: u64,
    /// controller sampling RNG, exact stream position
    pub controller_rng: Option<RngState>,
    /// task-generator RNG, exact stream position
    pub taskgen_rng: Option<RngState>,
}

fn encode_rng_state(w: &mut Writer, state: &Option<RngState>) {
    match state {
        None => w.u8(0),
        Some(s) => {
            w.u8(1);
            for word in s.s {
                w.u64(word);
            }
            match s.spare_normal_bits {
                None => w.u8(0),
                Some(bits) => {
                    w.u8(1);
                    w.u64(bits);
                }
            }
        }
    }
}

fn decode_rng_state(r: &mut Reader) -> Result<Option<RngState>> {
    match r.u8()? {
        0 => Ok(None),
        1 => {
            let mut s = [0u64; 4];
            for word in &mut s {
                *word = r.u64()?;
            }
            let spare_normal_bits = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                t => bail!("bad spare-normal tag {t}"),
            };
            Ok(Some(RngState { s, spare_normal_bits }))
        }
        t => bail!("bad rng-state tag {t}"),
    }
}

impl ShardState {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.rank as u64);
        w.u64(self.rng_seed);
        w.u64(self.opt_step);
        encode_rng_state(&mut w, &self.controller_rng);
        encode_rng_state(&mut w, &self.taskgen_rng);
        w.u32(self.params.len() as u32);
        for (name, set) in &self.params {
            w.str(name);
            w.tensors(&set.tensors);
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<ShardState> {
        let mut r = Reader::new(bytes);
        let rank = r.u64()? as usize;
        let rng_seed = r.u64()?;
        let opt_step = r.u64()?;
        let controller_rng = decode_rng_state(&mut r)?;
        let taskgen_rng = decode_rng_state(&mut r)?;
        let n = r.u32()? as usize;
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let tensors: Vec<Tensor> = r.tensors()?;
            params.push((name, ParamSet::new(tensors)));
        }
        r.expect_end()?;
        Ok(ShardState {
            rank,
            params,
            rng_seed,
            opt_step,
            controller_rng,
            taskgen_rng,
        })
    }

    /// Look up a named parameter set.
    pub fn param_set(&self, name: &str) -> Option<&ParamSet> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, set)| set)
    }
}

#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    pub step: u64,
    pub world_size: usize,
    pub loader: LoaderState,
}

pub struct CheckpointManager {
    dir: PathBuf,
    /// keep at most this many complete checkpoints
    pub max_keep: usize,
}

impl CheckpointManager {
    pub fn new(dir: impl AsRef<Path>) -> CheckpointManager {
        CheckpointManager { dir: dir.as_ref().to_path_buf(), max_keep: 3 }
    }

    fn step_dir(&self, step: u64) -> PathBuf {
        self.dir.join(format!("step_{step:010}"))
    }

    /// Synchronous save of one shard + (rank-0 only) the meta.
    pub fn save_shard(&self, step: u64, meta: &CheckpointMeta, shard: &ShardState) -> Result<()> {
        let dir = self.step_dir(step);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("shard_{}.bin", shard.rank));
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, shard.encode())?;
        std::fs::rename(&tmp, &path)?;
        if shard.rank == 0 {
            let meta_json = Json::obj(vec![
                ("step", Json::from(step as i64)),
                ("world_size", Json::from(meta.world_size)),
                ("loader_seed", Json::from(meta.loader.seed as i64)),
                ("loader_epoch", Json::from(meta.loader.epoch as i64)),
                ("loader_cursor", Json::from(meta.loader.cursor)),
            ]);
            let mpath = dir.join("meta.json");
            let mtmp = mpath.with_extension("tmp");
            std::fs::write(&mtmp, meta_json.to_string_pretty())?;
            std::fs::rename(&mtmp, &mpath)?;
        }
        self.gc()?;
        Ok(())
    }

    /// Asynchronous save: state is moved to a writer thread; returns a
    /// handle that reports completion.  Training proceeds immediately.
    pub fn save_async(
        &self,
        step: u64,
        meta: CheckpointMeta,
        shard: ShardState,
    ) -> AsyncSaveHandle {
        let mgr = CheckpointManager { dir: self.dir.clone(), max_keep: self.max_keep };
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let result = mgr.save_shard(step, &meta, &shard);
            tx.send(result).ok();
        });
        AsyncSaveHandle { rx, thread: Some(handle) }
    }

    /// On-demand checkpoint with a deadline.  Writes in bounded chunks,
    /// checking the clock; on overrun the partial output is removed and
    /// `Err` is returned (the caller releases resources immediately).
    pub fn save_with_deadline(
        &self,
        step: u64,
        meta: &CheckpointMeta,
        shard: &ShardState,
        deadline: Duration,
    ) -> Result<()> {
        use std::io::Write;
        let start = Instant::now();
        let dir = self.step_dir(step);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("shard_{}.bin", shard.rank));
        let tmp = path.with_extension("tmp");
        let bytes = shard.encode();
        let mut f = std::fs::File::create(&tmp)?;
        const CHUNK: usize = 1 << 20;
        for chunk in bytes.chunks(CHUNK) {
            if start.elapsed() > deadline {
                drop(f);
                std::fs::remove_file(&tmp).ok();
                bail!(
                    "checkpoint abandoned: deadline {:?} exceeded after {:?}",
                    deadline,
                    start.elapsed()
                );
            }
            f.write_all(chunk)?;
        }
        f.sync_all().ok();
        drop(f);
        std::fs::rename(&tmp, &path)?;
        if shard.rank == 0 {
            self.save_shard(step, meta, shard)?; // re-writes meta atomically
        }
        Ok(())
    }

    /// Latest step with a complete meta.json.
    pub fn latest_step(&self) -> Option<u64> {
        let entries = std::fs::read_dir(&self.dir).ok()?;
        let mut steps: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let step: u64 = name.strip_prefix("step_")?.parse().ok()?;
                e.path().join("meta.json").exists().then_some(step)
            })
            .collect();
        steps.sort_unstable();
        steps.pop()
    }

    /// Latest step whose checkpoint is complete for a `world`-rank resume:
    /// meta.json AND every `shard_<r>.bin` for r in 0..world must exist.
    /// This is the recovery anchor — a crash mid-save leaves a step with
    /// missing shards, which must never be chosen over an older complete
    /// one.
    pub fn latest_complete_step(&self, world: usize) -> Option<u64> {
        let entries = std::fs::read_dir(&self.dir).ok()?;
        let mut steps: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let step: u64 = name.strip_prefix("step_")?.parse().ok()?;
                let dir = e.path();
                let complete = dir.join("meta.json").exists()
                    && (0..world).all(|r| dir.join(format!("shard_{r}.bin")).exists());
                complete.then_some(step)
            })
            .collect();
        steps.sort_unstable();
        steps.pop()
    }

    pub fn load_meta(&self, step: u64) -> Result<CheckpointMeta> {
        let path = self.step_dir(step).join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text)?;
        Ok(CheckpointMeta {
            step: j.req("step")?.as_i64().context("step")? as u64,
            world_size: j.req("world_size")?.as_usize().context("world")?,
            loader: LoaderState {
                seed: j.req("loader_seed")?.as_i64().context("seed")? as u64,
                epoch: j.req("loader_epoch")?.as_i64().context("epoch")? as u64,
                cursor: j.req("loader_cursor")?.as_usize().context("cursor")?,
            },
        })
    }

    pub fn load_shard(&self, step: u64, rank: usize) -> Result<ShardState> {
        let path = self.step_dir(step).join(format!("shard_{rank}.bin"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        ShardState::decode(&bytes)
    }

    fn gc(&self) -> Result<()> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return Ok(()) };
        let mut steps: Vec<(u64, PathBuf)> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let step: u64 = name.strip_prefix("step_")?.parse().ok()?;
                Some((step, e.path()))
            })
            .collect();
        steps.sort_unstable_by_key(|(s, _)| *s);
        while steps.len() > self.max_keep {
            let (_, path) = steps.remove(0);
            std::fs::remove_dir_all(path).ok();
        }
        Ok(())
    }
}

pub struct AsyncSaveHandle {
    rx: mpsc::Receiver<Result<()>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl AsyncSaveHandle {
    /// Block until the background write finishes.
    pub fn wait(mut self) -> Result<()> {
        let result = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread died"))?;
        if let Some(t) = self.thread.take() {
            t.join().ok();
        }
        result
    }

    /// Non-blocking completion probe.
    pub fn is_done(&self) -> bool {
        match self.rx.try_recv() {
            Ok(_) | Err(mpsc::TryRecvError::Disconnected) => true,
            Err(mpsc::TryRecvError::Empty) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join("gcore_ckpt_tests")
            .join(format!("{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn shard(rank: usize, n: usize) -> ShardState {
        ShardState {
            rank,
            params: vec![(
                "policy".into(),
                ParamSet::new(vec![Tensor::f32(vec![n], (0..n).map(|i| i as f32).collect())]),
            )],
            rng_seed: 42,
            opt_step: 7,
            controller_rng: Some(crate::util::rng::Rng::new(9).state()),
            taskgen_rng: None,
        }
    }

    fn meta(step: u64) -> CheckpointMeta {
        CheckpointMeta {
            step,
            world_size: 2,
            loader: LoaderState { seed: 1, epoch: 2, cursor: 30 },
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mgr = CheckpointManager::new(tmpdir("roundtrip"));
        let s = shard(0, 100);
        mgr.save_shard(5, &meta(5), &s).unwrap();
        assert_eq!(mgr.latest_step(), Some(5));
        let m = mgr.load_meta(5).unwrap();
        assert_eq!(m.world_size, 2);
        assert_eq!(m.loader.cursor, 30);
        assert_eq!(mgr.load_shard(5, 0).unwrap(), s);
    }

    #[test]
    fn async_save_completes() {
        let mgr = CheckpointManager::new(tmpdir("async"));
        let h = mgr.save_async(7, meta(7), shard(0, 50_000));
        h.wait().unwrap();
        assert_eq!(mgr.latest_step(), Some(7));
        assert_eq!(mgr.load_shard(7, 0).unwrap().params[0].1.num_elements(), 50_000);
    }

    #[test]
    fn deadline_zero_abandons_cleanly() {
        let mgr = CheckpointManager::new(tmpdir("deadline"));
        let s = shard(0, 2_000_000);
        let err = mgr
            .save_with_deadline(9, &meta(9), &s, Duration::from_nanos(1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("abandoned"), "{err}");
        // no partial files left behind
        assert_eq!(mgr.latest_step(), None);
        let step_dir = mgr.step_dir(9);
        if step_dir.exists() {
            let leftovers: Vec<_> = std::fs::read_dir(step_dir).unwrap().flatten().collect();
            assert!(leftovers.is_empty(), "{leftovers:?}");
        }
    }

    #[test]
    fn generous_deadline_succeeds() {
        let mgr = CheckpointManager::new(tmpdir("deadline_ok"));
        mgr.save_with_deadline(3, &meta(3), &shard(0, 1000), Duration::from_secs(30))
            .unwrap();
        assert_eq!(mgr.latest_step(), Some(3));
    }

    #[test]
    fn gc_keeps_max_checkpoints() {
        let mgr = CheckpointManager::new(tmpdir("gc"));
        for step in 1..=6 {
            mgr.save_shard(step, &meta(step), &shard(0, 10)).unwrap();
        }
        assert_eq!(mgr.latest_step(), Some(6));
        assert!(mgr.load_shard(1, 0).is_err(), "old checkpoints pruned");
        assert!(mgr.load_shard(6, 0).is_ok());
    }

    #[test]
    fn multi_shard_checkpoint() {
        let mgr = CheckpointManager::new(tmpdir("shards"));
        for rank in 0..4 {
            mgr.save_shard(2, &meta(2), &shard(rank, 10 + rank)).unwrap();
        }
        for rank in 0..4 {
            assert_eq!(mgr.load_shard(2, rank).unwrap().rank, rank);
        }
    }

    #[test]
    fn shard_rng_states_roundtrip_exactly() {
        // the resume-critical payload: a drained RNG state must come back
        // bit-identical, spare normal included
        let mut rng = crate::util::rng::Rng::new(1234);
        let _ = rng.normal(); // arm the spare-normal slot
        let s = ShardState {
            rank: 3,
            params: vec![],
            rng_seed: 77,
            opt_step: 12,
            controller_rng: Some(rng.state()),
            taskgen_rng: Some(crate::util::rng::Rng::new(5).state()),
        };
        let back = ShardState::decode(&s.encode()).unwrap();
        assert_eq!(back, s);
        let mut a = crate::util::rng::Rng::from_state(back.controller_rng.unwrap());
        let mut b = rng;
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn latest_complete_step_requires_all_shards() {
        let mgr = CheckpointManager::new(tmpdir("complete"));
        // step 4: full 2-rank checkpoint
        mgr.save_shard(4, &meta(4), &shard(0, 8)).unwrap();
        mgr.save_shard(4, &meta(4), &shard(1, 8)).unwrap();
        // step 6: rank 0 landed, rank 1's shard is missing (crash mid-save)
        mgr.save_shard(6, &meta(6), &shard(0, 8)).unwrap();
        assert_eq!(mgr.latest_step(), Some(6), "meta-only view sees step 6");
        assert_eq!(
            mgr.latest_complete_step(2),
            Some(4),
            "recovery must fall back to the last step with every shard"
        );
        assert_eq!(mgr.latest_complete_step(1), Some(6), "world=1 needs only shard 0");
        assert_eq!(mgr.latest_complete_step(3), None, "no 3-rank checkpoint exists");
    }
}
