//! Context-parallel distributed attention cost models (paper §4.5).
//!
//! G-Core replaces ring attention with **CCL all-gather KV + head-chunked
//! local attention**: all-gather K/V across CP ranks, then compute the
//! local Q chunk's attention "processing only a subset of attention heads
//! at a time and overlap[ping] KV communication with attention
//! computation", which "makes it feasible to train sequences up to
//! 1 million tokens".  It also supports arbitrary attention masks (e.g.
//! Gemma-3 block masks) which ring attention's causal pipelining cannot.
//!
//! These closed-form models regenerate the E5 feasibility/throughput table;
//! the single-chip kernel analogue is `python/compile/kernels/attention.py`.

use crate::cluster::topology::Topology;

#[derive(Debug, Clone)]
pub struct AttnConfig {
    pub seq_len: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// context-parallel degree
    pub cp: usize,
    /// bytes per element (bf16 = 2)
    pub elem_bytes: usize,
    /// heads processed per chunk in the all-gather scheme
    pub head_chunk: usize,
    /// device peak compute for attention matmuls, FLOP/s
    pub flops_per_sec: f64,
    /// HBM budget available for attention working set, bytes
    pub hbm_budget: usize,
}

impl AttnConfig {
    pub fn h20_default(seq_len: usize, cp: usize) -> AttnConfig {
        AttnConfig {
            seq_len,
            n_heads: 32,
            d_head: 128,
            cp,
            elem_bytes: 2,
            head_chunk: 4,
            flops_per_sec: 120e12, // H20 bf16 dense ≈ 148 TFLOPs, ~80% eff.
            // HBM left for the attention working set after weights,
            // optimizer shards and activations on a 96 GB card
            hbm_budget: 16 * (1 << 30),
        }
    }

    fn local_seq(&self) -> usize {
        self.seq_len / self.cp
    }

    /// Attention FLOPs computed by one rank (causal → half).
    fn local_flops(&self) -> f64 {
        // each rank computes q_local (S/cp) against full K/V (S):
        // 2 matmuls × 2 flops × (S/cp) × S × d per head, halved for causal
        2.0 * 2.0
            * self.local_seq() as f64
            * self.seq_len as f64
            * self.d_head as f64
            * self.n_heads as f64
            / 2.0
    }

    pub fn compute_time(&self) -> f64 {
        self.local_flops() / self.flops_per_sec
    }
}

/// Per-rank peak memory + step time of one scheme.
#[derive(Debug, Clone)]
pub struct AttnCost {
    pub scheme: &'static str,
    pub peak_mem_bytes: usize,
    pub comm_bytes: usize,
    pub comm_time: f64,
    pub compute_time: f64,
    /// wallclock with comm/compute overlap applied
    pub step_time: f64,
    pub feasible: bool,
    /// supports arbitrary (non-causal-pipelined) masks
    pub arbitrary_masks: bool,
}

/// Ring attention: K/V rotate around the ring in cp-1 steps; each rank
/// holds q,k,v chunks (S/cp) plus one in-flight K/V chunk.
pub fn ring_attention_cost(cfg: &AttnConfig, topo: &Topology) -> AttnCost {
    let s_local = cfg.local_seq();
    let hd = cfg.n_heads * cfg.d_head;
    let chunk_kv = 2 * s_local * hd * cfg.elem_bytes;
    // q,k,v local + recv buffer + accumulators(f32 o + stats)
    let peak = 3 * s_local * hd * cfg.elem_bytes      // q,k,v chunks
        + 2 * chunk_kv                                 // double-buffered in-flight kv
        + s_local * hd * 4                             // f32 output accumulator
        + 2 * s_local * cfg.n_heads * 4; // running max/denom
    let group: Vec<_> = (0..cfg.cp).map(crate::cluster::device::DeviceId).collect();
    // cp-1 ring hops, each sending one KV chunk; per-step comm overlaps the
    // per-step compute (classic ring pipeline)
    let hop_time = topo.p2p_time(group[0], group[cfg.cp.min(2) - 1], chunk_kv as f64);
    let comm_bytes = (cfg.cp - 1) * chunk_kv;
    let comm_time = (cfg.cp - 1) as f64 * hop_time;
    let per_step_compute = cfg.compute_time() / cfg.cp as f64;
    let step_time = (0..cfg.cp)
        .map(|_| per_step_compute.max(hop_time))
        .sum::<f64>();
    AttnCost {
        scheme: "ring",
        peak_mem_bytes: peak,
        comm_bytes,
        comm_time,
        compute_time: cfg.compute_time(),
        step_time,
        feasible: peak <= cfg.hbm_budget,
        arbitrary_masks: false, // causal pipelining bakes the mask structure in
    }
}

/// G-Core all-gather KV with head chunking: gather K/V for `head_chunk`
/// heads at a time; compute local-Q attention for those heads while the
/// next head-chunk's K/V is in flight (comm/compute overlap).
pub fn allgather_attention_cost(cfg: &AttnConfig, topo: &Topology) -> AttnCost {
    let s_local = cfg.local_seq();
    let hd = cfg.n_heads * cfg.d_head;
    let chunk_heads = cfg.head_chunk.min(cfg.n_heads);
    // gathered K/V for one head chunk spans the FULL sequence
    let gathered_chunk = 2 * cfg.seq_len * chunk_heads * cfg.d_head * cfg.elem_bytes;
    let peak = 3 * s_local * hd * cfg.elem_bytes      // local q,k,v
        + 2 * gathered_chunk                           // current + prefetch chunk
        + s_local * hd * 4; // f32 output
    let group: Vec<_> = (0..cfg.cp).map(crate::cluster::device::DeviceId).collect();
    // all-gather per head chunk: each rank contributes its local slice
    let per_chunk_bytes = 2 * s_local * chunk_heads * cfg.d_head * cfg.elem_bytes;
    let n_chunks = cfg.n_heads / chunk_heads;
    let per_chunk_comm = topo.allgather_time(&group, per_chunk_bytes as f64);
    let comm_bytes = n_chunks * (cfg.cp - 1) * per_chunk_bytes;
    let comm_time = n_chunks as f64 * per_chunk_comm;
    let per_chunk_compute = cfg.compute_time() / n_chunks as f64;
    // first chunk's gather is exposed; the rest overlap with compute
    let step_time = per_chunk_comm
        + (0..n_chunks)
            .map(|_| per_chunk_compute.max(per_chunk_comm))
            .sum::<f64>();
    AttnCost {
        scheme: "allgather_kv",
        peak_mem_bytes: peak,
        comm_bytes,
        comm_time,
        compute_time: cfg.compute_time(),
        step_time,
        feasible: peak <= cfg.hbm_budget,
        arbitrary_masks: true,
    }
}

/// Naive all-gather (no head chunking) — the memory blow-up the paper's
/// head chunking exists to avoid.
pub fn allgather_naive_cost(cfg: &AttnConfig, topo: &Topology) -> AttnCost {
    let mut full = AttnConfig { head_chunk: cfg.n_heads, ..cfg.clone() };
    full.head_chunk = cfg.n_heads;
    let mut c = allgather_attention_cost(&full, topo);
    c.scheme = "allgather_full";
    c
}

/// Max trainable sequence length under the HBM budget (bisection).
pub fn max_feasible_seq(
    cfg_for: impl Fn(usize) -> AttnConfig,
    cost: impl Fn(&AttnConfig) -> AttnCost,
) -> usize {
    let mut lo = 1 << 10;
    let mut hi = 1 << 26; // 64M tokens — beyond any plausible budget
    if !cost(&cfg_for(lo)).feasible {
        return 0;
    }
    while hi - lo > 1 << 10 {
        let mid = (lo + hi) / 2;
        if cost(&cfg_for(mid)).feasible {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::paper_testbed()
    }

    #[test]
    fn head_chunking_bounds_memory() {
        let cfg = AttnConfig::h20_default(1 << 20, 64); // 1M tokens, 64 ranks
        let chunked = allgather_attention_cost(&cfg, &topo());
        let naive = allgather_naive_cost(&cfg, &topo());
        assert!(
            chunked.peak_mem_bytes < naive.peak_mem_bytes / 4,
            "chunked {} vs naive {}",
            chunked.peak_mem_bytes,
            naive.peak_mem_bytes
        );
    }

    #[test]
    fn paper_claim_1m_tokens_feasible() {
        // the headline §4.5 claim: head-chunked all-gather trains 1M tokens
        let cfg = AttnConfig::h20_default(1 << 20, 64);
        let c = allgather_attention_cost(&cfg, &topo());
        assert!(c.feasible, "peak {} > budget {}", c.peak_mem_bytes, cfg.hbm_budget);
        // while the un-chunked gather does NOT fit
        let n = allgather_naive_cost(&cfg, &topo());
        assert!(!n.feasible);
    }

    #[test]
    fn ring_memory_smaller_but_masks_inflexible() {
        let cfg = AttnConfig::h20_default(1 << 18, 16);
        let ring = ring_attention_cost(&cfg, &topo());
        let ag = allgather_attention_cost(&cfg, &topo());
        assert!(ring.peak_mem_bytes < ag.peak_mem_bytes);
        assert!(!ring.arbitrary_masks && ag.arbitrary_masks);
    }

    #[test]
    fn overlap_hides_most_comm() {
        let cfg = AttnConfig::h20_default(1 << 19, 16);
        let ag = allgather_attention_cost(&cfg, &topo());
        // step time must be well under compute + full comm (overlap works)
        assert!(ag.step_time < ag.compute_time + ag.comm_time * 0.9);
        assert!(ag.step_time >= ag.compute_time * 0.99);
    }

    #[test]
    fn feasible_seq_grows_with_cp() {
        let max8 = max_feasible_seq(
            |s| AttnConfig::h20_default(s, 8),
            |c| allgather_attention_cost(c, &topo()),
        );
        let max64 = max_feasible_seq(
            |s| AttnConfig::h20_default(s, 64),
            |c| allgather_attention_cost(c, &topo()),
        );
        // grows with cp but saturates: the gathered-KV term is cp-independent
        assert!(max64 > max8, "cp=8 → {max8}, cp=64 → {max64}");
        assert!(max64 >= 1 << 20, "64-way CP must reach 1M tokens: {max64}");
    }

    #[test]
    fn compute_time_scales_quadratically() {
        let t1 = AttnConfig::h20_default(1 << 16, 8).compute_time();
        let t2 = AttnConfig::h20_default(1 << 17, 8).compute_time();
        assert!((t2 / t1 - 4.0).abs() < 0.1, "{}", t2 / t1);
    }
}
