//! Persistent benchmarking: typed experiment tables, a durable results
//! store, trend reports and the CI regression gate.
//!
//! The redesign this module anchors (ISSUE 8): `experiments::Table` rows
//! are typed `Metric` cells instead of pre-formatted strings, so the
//! same cells that render the markdown/JSON tables also feed the store
//! losslessly — `gcore bench run` ingests each run keyed by experiment
//! label × metric × commit × timestamp, `gcore bench report` renders
//! per-experiment trends (table / .dat / latex), and `gcore bench gate`
//! fails CI when a directed metric regresses past the rolling median of
//! the last K commits.

pub mod gate;
pub mod metric;
pub mod report;
pub mod store;
pub mod table;

pub use gate::{gate, GateReport, SeriesVerdict, Verdict};
pub use metric::Metric;
pub use report::{render as render_report, ReportFormat};
pub use store::{median, BenchDb, Bless, Direction, Sample};
pub use table::{Table, TABLE_SCHEMA_VERSION};

use anyhow::Result;

/// Ingest one experiment table into the store.
///
/// Row identity: the first `key_cols` cells of each row, rendered and
/// joined under the experiment id — "e8c/4/4.19 MB/ring (tcp)".  Every
/// remaining cell that carries a numeric value becomes one sample whose
/// metric name is its column header; Text/Bool cells are display-only.
/// Timing distributions attached to the table (`Table::timing`) are
/// ingested with full percentile columns under their own labels.
/// Returns the number of samples inserted.
pub fn ingest_table(
    db: &mut BenchDb,
    id: &str,
    table: &Table,
    key_cols: usize,
    commit: &str,
    timestamp: u64,
) -> Result<usize> {
    let mut inserted = 0;
    for row in &table.rows {
        if row.is_empty() {
            continue;
        }
        let key_cols = key_cols.clamp(1, row.len());
        let label = std::iter::once(id.to_string())
            .chain(row[..key_cols].iter().map(Metric::render))
            .collect::<Vec<_>>()
            .join("/");
        for (col, cell) in row.iter().enumerate().skip(key_cols) {
            let Some(value) = cell.value() else {
                continue;
            };
            let metric = table
                .header
                .get(col)
                .cloned()
                .unwrap_or_else(|| format!("col{col}"));
            let unit = cell.unit_str().unwrap_or("").to_string();
            let direction = Direction::infer(&metric, &unit);
            db.insert(Sample::scalar(&label, &metric, commit, timestamp, value, unit, direction))?;
            inserted += 1;
        }
    }
    for (label, r) in &table.timing {
        db.insert(timing_sample(label, r, commit, timestamp))?;
        inserted += 1;
    }
    Ok(inserted)
}

/// A `util::bench::BenchResult` as one store sample: the headline value
/// is the mean wall-clock in ns, with the measured percentiles alongside.
pub fn timing_sample(
    label: &str,
    r: &crate::util::bench::BenchResult,
    commit: &str,
    timestamp: u64,
) -> Sample {
    let mut s = Sample::scalar(
        label,
        "wall ns",
        commit,
        timestamp,
        r.mean_ns(),
        "ns",
        Direction::LowerIsBetter,
    );
    s.p50 = Some(r.p50_ns());
    s.p90 = Some(r.p90_ns());
    s.p99 = Some(r.p99_ns());
    s.mean = Some(r.mean_ns());
    s.iters = Some(r.iters as u64);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::time::Duration;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gcore_ingest_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn ingest_keys_rows_and_skips_text() {
        let path = tmp("rows");
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        let t = Table {
            title: "T".into(),
            header: vec!["world".into(), "payload".into(), "ms/round".into(), "ok".into()],
            rows: vec![
                vec![
                    4usize.into(),
                    Metric::f64_unit(4.19, 2, "MB"),
                    Metric::f64(1.5, 3),
                    true.into(),
                ],
                vec![
                    8usize.into(),
                    Metric::f64_unit(4.19, 2, "MB"),
                    Metric::f64(2.5, 3),
                    true.into(),
                ],
            ],
            ..Table::default()
        };
        let n = ingest_table(&mut db, "e8c", &t, 2, "c1", 42).unwrap();
        // one numeric non-key column per row; Bool column carries no value
        assert_eq!(n, 2);
        let keys = db.series_keys();
        assert_eq!(
            keys,
            vec![
                ("e8c/4/4.19 MB".to_string(), "ms/round".to_string()),
                ("e8c/8/4.19 MB".to_string(), "ms/round".to_string()),
            ]
        );
        let s = db.series("e8c/4/4.19 MB", "ms/round");
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].value, 1.5);
        assert_eq!(s[0].direction, Direction::LowerIsBetter);
        assert_eq!(s[0].commit, "c1");
        assert_eq!(s[0].timestamp, 42);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ingest_timing_carries_percentiles() {
        let path = tmp("timing");
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        let r = crate::util::bench::BenchResult {
            name: "decode".into(),
            iters: 10,
            mean: Duration::from_micros(100),
            p50: Duration::from_micros(90),
            p90: Duration::from_micros(150),
            p95: Duration::from_micros(160),
            p99: Duration::from_micros(190),
            min: Duration::from_micros(80),
            max: Duration::from_micros(200),
        };
        let t = Table {
            title: "T".into(),
            header: vec!["case".into()],
            rows: vec![vec!["a".into()]],
            timing: vec![("einterp/tiny/decode".into(), r)],
        };
        let n = ingest_table(&mut db, "einterp", &t, 1, "c1", 1).unwrap();
        assert_eq!(n, 1, "text-only row contributes nothing; timing does");
        let s = db.series("einterp/tiny/decode", "wall ns");
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].value, 100_000.0);
        assert_eq!(s[0].p50, Some(90_000.0));
        assert_eq!(s[0].p90, Some(150_000.0));
        assert_eq!(s[0].p99, Some(190_000.0));
        assert_eq!(s[0].iters, Some(10));
        assert_eq!(s[0].direction, Direction::LowerIsBetter);
        std::fs::remove_file(&path).ok();
    }
}
