//! Trend rendering over the bench store (`gcore bench report`).
//!
//! Follows the bencher CLI idiom: one trend table per experiment label
//! (cli_table), plus `.dat` (gnuplot columns) and LaTeX tabular exports
//! for the paper-shaped figures.

use super::gate::regression_pct;
use super::store::{median, BenchDb, Direction, Sample};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReportFormat {
    Table,
    Dat,
    Latex,
}

impl ReportFormat {
    pub fn parse(s: &str) -> anyhow::Result<ReportFormat> {
        Ok(match s {
            "table" => ReportFormat::Table,
            "dat" => ReportFormat::Dat,
            "latex" => ReportFormat::Latex,
            other => anyhow::bail!("unknown report format '{other}' (table|dat|latex)"),
        })
    }
}

/// How many trailing per-commit medians the table's history column shows.
const HISTORY_LEN: usize = 5;

/// Significant-but-compact number formatting for report cells: integers
/// stay integers, everything else gets enough precision to be readable.
fn fmt_val(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        return format!("{v:.0}");
    }
    if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Per-commit medians (commit, median), oldest first, for one series.
fn trend(series: &[&Sample]) -> Vec<(String, f64)> {
    let mut order: Vec<String> = Vec::new();
    for s in series {
        if !order.contains(&s.commit) {
            order.push(s.commit.clone());
        }
    }
    order
        .into_iter()
        .filter_map(|c| {
            let vals: Vec<f64> =
                series.iter().filter(|s| s.commit == c).map(|s| s.value).collect();
            median(&vals).map(|m| (c, m))
        })
        .collect()
}

fn labels_matching(db: &BenchDb, filter: Option<&str>) -> Vec<String> {
    db.labels()
        .into_iter()
        .filter(|l| match filter {
            None => true,
            Some(f) => l == f || l.starts_with(&format!("{f}/")),
        })
        .collect()
}

/// Render the trend report for every label matching `filter` (None = all;
/// "e8c" also matches "e8c/…").  `window` is the rolling-median width the
/// Δ% column compares the latest commit against — keep it equal to the
/// gate's `--window` so the report explains the gate's verdicts.
pub fn render(db: &BenchDb, filter: Option<&str>, format: ReportFormat, window: usize) -> String {
    let labels = labels_matching(db, filter);
    match format {
        ReportFormat::Table => render_table(db, &labels, window),
        ReportFormat::Dat => render_dat(db, &labels),
        ReportFormat::Latex => render_latex(db, &labels, window),
    }
}

/// The Δ% cell: latest commit's median vs the rolling median of the up to
/// `window` commits before it (the gate's baseline rule).
fn delta_cell(tr: &[(String, f64)], direction: Direction, window: usize) -> String {
    if tr.len() < 2 || direction == Direction::Informational {
        return "-".to_string();
    }
    let (_, latest) = &tr[tr.len() - 1];
    let prior: Vec<f64> = tr[..tr.len() - 1]
        .iter()
        .rev()
        .take(window.max(1))
        .map(|(_, m)| *m)
        .collect();
    let Some(base) = median(&prior) else {
        return "-".to_string();
    };
    match regression_pct(direction, base, *latest) {
        Some(r) => format!("{:+.1}%", -r), // display improvement as positive
        None => "-".to_string(),
    }
}

fn series_rows(db: &BenchDb, label: &str, window: usize) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for (l, metric) in db.series_keys() {
        if l != label {
            continue;
        }
        let series = db.series(&l, &metric);
        if series.is_empty() {
            continue;
        }
        let direction = series.last().map(|s| s.direction).unwrap_or(Direction::Informational);
        let unit = series.last().map(|s| s.unit.clone()).unwrap_or_default();
        let tr = trend(&series);
        let shown = &tr[tr.len().saturating_sub(HISTORY_LEN)..];
        let history = shown
            .iter()
            .map(|(_, m)| fmt_val(*m))
            .collect::<Vec<_>>()
            .join(" → ");
        let (latest_commit, latest) = match tr.last() {
            Some(t) => t.clone(),
            None => continue,
        };
        rows.push(vec![
            metric,
            direction.as_str().to_string(),
            unit,
            tr.len().to_string(),
            history,
            format!("{} @ {latest_commit}", fmt_val(latest)),
            delta_cell(&tr, direction, window),
        ]);
    }
    rows
}

fn render_table(db: &BenchDb, labels: &[String], window: usize) -> String {
    if labels.is_empty() {
        return "bench report: no matching series in the database\n".to_string();
    }
    let mut out = String::new();
    for label in labels {
        let rows = series_rows(db, label, window);
        out.push_str(&crate::util::bench::format_rows(
            label,
            &[
                "metric",
                "dir",
                "unit",
                "commits",
                &format!("last {HISTORY_LEN} medians"),
                "latest",
                "Δ%",
            ],
            &rows,
        ));
    }
    out
}

/// Gnuplot-friendly: one block per series, blank-line separated —
/// `plot 'bench.dat' index N using 1:4` plots series N's trend.
fn render_dat(db: &BenchDb, labels: &[String]) -> String {
    let mut out = String::new();
    for label in labels {
        for (l, metric) in db.series_keys() {
            if &l != label {
                continue;
            }
            let series = db.series(&l, &metric);
            if series.is_empty() {
                continue;
            }
            out.push_str(&format!("# {label} :: {metric}\n"));
            out.push_str("# idx timestamp commit median\n");
            for (i, (commit, m)) in trend(&series).iter().enumerate() {
                let ts = series
                    .iter()
                    .filter(|s| &s.commit == commit)
                    .map(|s| s.timestamp)
                    .max()
                    .unwrap_or(0);
                out.push_str(&format!("{i} {ts} {commit} {m}\n"));
            }
            out.push('\n');
        }
    }
    out
}

fn latex_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '%' => out.push_str("\\%"),
            '&' => out.push_str("\\&"),
            '#' => out.push_str("\\#"),
            '_' => out.push_str("\\_"),
            '$' => out.push_str("\\$"),
            '{' => out.push_str("\\{"),
            '}' => out.push_str("\\}"),
            '→' => out.push_str("$\\rightarrow$"),
            'Δ' => out.push_str("$\\Delta$"),
            '×' => out.push_str("$\\times$"),
            'µ' => out.push_str("$\\mu$"),
            c => out.push(c),
        }
    }
    out
}

fn render_latex(db: &BenchDb, labels: &[String], window: usize) -> String {
    let mut out = String::new();
    for label in labels {
        let rows = series_rows(db, label, window);
        out.push_str(&format!(
            "% trend table for {label}\n\\begin{{tabular}}{{lllrllr}}\n\\hline\n"
        ));
        out.push_str(&format!(
            "metric & dir & unit & commits & last {HISTORY_LEN} medians & latest & $\\Delta$\\% \\\\\n\\hline\n"
        ));
        for row in rows {
            let cells: Vec<String> = row.iter().map(|c| latex_escape(c)).collect();
            out.push_str(&format!("{} \\\\\n", cells.join(" & ")));
        }
        out.push_str("\\hline\n\\end{tabular}\n\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gcore_report_{}_{name}.jsonl", std::process::id()))
    }

    fn sample_db(name: &str) -> BenchDb {
        let path = tmp(name);
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        for (c, ts, v) in [("c1", 1u64, 10.0), ("c2", 2, 10.2), ("c3", 3, 9.8)] {
            let s =
                Sample::scalar("e8c/4/ring", "ms/round", c, ts, v, "ms", Direction::LowerIsBetter);
            db.insert(s).unwrap();
        }
        db.insert(Sample::scalar(
            "egen/16",
            "tokens/s",
            "c3",
            3,
            1234.0,
            "",
            Direction::HigherIsBetter,
        ))
        .unwrap();
        std::fs::remove_file(&path).ok();
        db
    }

    #[test]
    fn table_report_renders_all_series() {
        let db = sample_db("table");
        let out = render(&db, None, ReportFormat::Table, 5);
        assert!(out.contains("### e8c/4/ring"));
        assert!(out.contains("### egen/16"));
        assert!(out.contains("ms/round"));
        assert!(out.contains("10 → 10.20 → 9.80"));
        assert!(out.contains("9.80 @ c3"));
        // improvement vs median{10, 10.2} = 10.1: shown as positive Δ
        assert!(out.contains("+3.0%"), "got:\n{out}");
    }

    #[test]
    fn label_filter_prefix_matches() {
        let db = sample_db("filter");
        let out = render(&db, Some("e8c"), ReportFormat::Table, 5);
        assert!(out.contains("e8c/4/ring"));
        assert!(!out.contains("egen/16"));
        let none = render(&db, Some("nope"), ReportFormat::Table, 5);
        assert!(none.contains("no matching series"));
    }

    #[test]
    fn dat_report_has_one_block_per_series() {
        let db = sample_db("dat");
        let out = render(&db, None, ReportFormat::Dat, 5);
        assert!(out.contains("# e8c/4/ring :: ms/round"));
        assert!(out.contains("0 1 c1 10\n1 2 c2 10.2\n2 3 c3 9.8\n"));
        assert!(out.contains("# egen/16 :: tokens/s"));
    }

    #[test]
    fn latex_report_escapes_and_tabulates() {
        let db = sample_db("latex");
        let out = render(&db, Some("e8c"), ReportFormat::Latex, 5);
        assert!(out.contains("\\begin{tabular}"));
        assert!(out.contains("ms/round"));
        assert!(out.contains("$\\rightarrow$"));
        assert!(!out.contains('→'));
        assert!(out.contains("\\end{tabular}"));
    }

    #[test]
    fn fmt_val_shapes() {
        assert_eq!(fmt_val(10.0), "10");
        assert_eq!(fmt_val(10.2), "10.20");
        assert_eq!(fmt_val(1234.5), "1234.5");
        assert_eq!(fmt_val(0.1234), "0.1234");
        assert_eq!(fmt_val(-3.0), "-3");
    }
}
