//! The experiment table, redesigned over typed cells.
//!
//! Formerly `experiments::Table` with `rows: Vec<Vec<String>>`; now rows
//! are `Vec<Vec<Metric>>` and markdown/JSON are renderers.  The JSON form
//! stays schema-compatible with the legacy artifact shape (title/header/
//! rows-of-strings) and adds `schema_version` plus, when an experiment
//! measured wall-clock distributions, a `timing` block with the
//! p50/p90/p99 percentiles `util::bench::BenchResult` now surfaces.

use super::metric::Metric;
use crate::util::bench::BenchResult;
use crate::util::json::Json;

/// JSON schema version of `Table::to_json`.  Version 1 (implicit — the
/// field was absent) was title/header/rows-of-strings; version 2 renders
/// identically, adds this field, and may carry a `timing` array.
pub const TABLE_SCHEMA_VERSION: i64 = 2;

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<Metric>>,
    /// Wall-clock distributions attached by experiments that time per-case
    /// sample loops (label, stats).  Ingested into the bench DB with full
    /// percentile columns; rendered tables only show derived cells.
    pub timing: Vec<(String, BenchResult)>,
}

impl Table {
    /// The legacy stringly rows — every cell rendered.  Rendering is
    /// bit-identical to what the pre-typed tables carried.
    pub fn rendered_rows(&self) -> Vec<Vec<String>> {
        self.rows
            .iter()
            .map(|r| r.iter().map(Metric::render).collect())
            .collect()
    }

    pub fn print(&self) {
        let header: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        crate::util::bench::print_rows(&self.title, &header, &self.rendered_rows());
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!(
            "### {}\n\n| {} |\n|{}|\n",
            self.title,
            self.header.join(" | "),
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in self.rendered_rows() {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    /// Machine-readable form (`gcore bench run --json`; uploaded as a CI
    /// artifact by the bench-smoke job).  Rows render to the same strings
    /// the legacy schema carried; `schema_version` marks the typed era.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("schema_version".to_string(), Json::Num(TABLE_SCHEMA_VERSION as f64));
        m.insert("title".to_string(), Json::Str(self.title.clone()));
        m.insert(
            "header".to_string(),
            Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        m.insert(
            "rows".to_string(),
            Json::Arr(
                self.rendered_rows()
                    .into_iter()
                    .map(|r| Json::Arr(r.into_iter().map(Json::Str).collect()))
                    .collect(),
            ),
        );
        if !self.timing.is_empty() {
            m.insert(
                "timing".to_string(),
                Json::Arr(
                    self.timing
                        .iter()
                        .map(|(label, r)| {
                            Json::obj(vec![
                                ("label", Json::Str(label.clone())),
                                ("iters", Json::from(r.iters)),
                                ("mean_ns", Json::from(r.mean_ns())),
                                ("p50_ns", Json::from(r.p50_ns())),
                                ("p90_ns", Json::from(r.p90_ns())),
                                ("p99_ns", Json::from(r.p99_ns())),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_table() -> Table {
        Table {
            title: "T".into(),
            header: vec!["case".into(), "x".into(), "ok".into()],
            rows: vec![
                vec!["a".into(), Metric::f64(1.25, 2), true.into()],
                vec!["b".into(), Metric::f64_unit(2.0, 1, "MB"), false.into()],
            ],
            ..Table::default()
        }
    }

    #[test]
    fn markdown_renders_typed_cells() {
        let md = sample_table().to_markdown();
        assert!(md.contains("| a | 1.25 | true |"));
        assert!(md.contains("| b | 2.0 MB | false |"));
    }

    #[test]
    fn json_is_legacy_shape_plus_version() {
        let j = sample_table().to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_i64), Some(2));
        assert_eq!(j.get("title").and_then(Json::as_str), Some("T"));
        let rows = j.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        // rows are still arrays of strings, exactly like schema v1
        assert_eq!(rows[0].as_arr().unwrap()[1].as_str(), Some("1.25"));
        assert!(j.get("timing").is_none(), "no timing block when empty");
    }
}
