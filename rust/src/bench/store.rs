//! Persistent bench results store.
//!
//! Schema follows the sqlite-backed results store of `bsdinis/bencher`
//! (one `results` row per experiment label × metric × commit × timestamp,
//! with percentile columns), but the storage engine is a from-scratch
//! crash-safe append-only log: the offline build closure has no `rusqlite`
//! (same constraint that gave us `util::json` instead of serde and
//! `util::cli` instead of clap).  The file is line-oriented JSON — a
//! header line `{"benchdb": 1}` followed by one record per line — so
//! inserts are O(1) appends, a torn final line from a crashed writer is
//! detected and dropped, and the file diffs/caches cleanly in CI.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// On-disk format version (the `{"benchdb": N}` header line).
pub const DB_FORMAT_VERSION: i64 = 1;

/// Which way a metric is supposed to move.  Only directed metrics are
/// eligible for the regression gate; `Informational` series are stored
/// and reported but never gated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    Informational,
}

impl Direction {
    pub fn as_str(&self) -> &'static str {
        match self {
            Direction::HigherIsBetter => "higher",
            Direction::LowerIsBetter => "lower",
            Direction::Informational => "info",
        }
    }

    pub fn parse(s: &str) -> Result<Direction> {
        Ok(match s {
            "higher" => Direction::HigherIsBetter,
            "lower" => Direction::LowerIsBetter,
            "info" => Direction::Informational,
            other => bail!("unknown direction '{other}'"),
        })
    }

    /// Infer polarity from a metric's column name and unit.  Rates and
    /// utilization go up; latencies, residency and waste go down; counts
    /// with no obvious polarity stay informational (never gated).
    pub fn infer(metric: &str, unit: &str) -> Direction {
        let m = metric.to_ascii_lowercase();
        let u = unit.to_ascii_lowercase();
        // rates & ratios first: "agg MB/s" must win over the "mb" rule below
        if m.ends_with("/s")
            || m.ends_with("/h")
            || ["speedup", "util", "throughput", "hits", "per sec"].iter().any(|k| m.contains(k))
        {
            return Direction::HigherIsBetter;
        }
        if ["ms", "µs", " ns", " s", "wall", "waste", "bubble", "makespan", "swap", "bytes",
            "mb", "kb", "gb", "peak", "blocked", "latency"]
        .iter()
        .any(|k| m.contains(k))
        {
            return Direction::LowerIsBetter;
        }
        if u.ends_with("/s") {
            return Direction::HigherIsBetter;
        }
        if ["ns", "µs", "ms", "s", "b", "kib", "mib", "gib", "mb", "kb", "gb"].contains(&u.as_str())
        {
            return Direction::LowerIsBetter;
        }
        Direction::Informational
    }
}

/// One measurement: the results-table row of the bencher schema.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Series identity: "<experiment>/<case key>" (e.g. "e8c/4/4.19 MB/ring (tcp)").
    pub label: String,
    /// Metric name within the series — the table column ("ms/round").
    pub metric: String,
    /// Commit the run measured (short SHA or synthetic id in tests).
    pub commit: String,
    /// Unix seconds when the run recorded the sample.
    pub timestamp: u64,
    /// Headline scalar (the rendered cell's value).
    pub value: f64,
    /// Display unit ("" when the column header carries it).
    pub unit: String,
    pub direction: Direction,
    /// Distribution columns, present when the producer measured a sample
    /// loop (`util::bench::BenchResult`) rather than a single scalar.
    pub p50: Option<f64>,
    pub p90: Option<f64>,
    pub p99: Option<f64>,
    pub mean: Option<f64>,
    pub iters: Option<u64>,
}

impl Sample {
    /// A scalar sample with no distribution columns.
    pub fn scalar(
        label: impl Into<String>,
        metric: impl Into<String>,
        commit: impl Into<String>,
        timestamp: u64,
        value: f64,
        unit: impl Into<String>,
        direction: Direction,
    ) -> Sample {
        Sample {
            label: label.into(),
            metric: metric.into(),
            commit: commit.into(),
            timestamp,
            value,
            unit: unit.into(),
            direction,
            p50: None,
            p90: None,
            p99: None,
            mean: None,
            iters: None,
        }
    }

    fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![(
            "sample",
            Json::obj(vec![
                ("label", Json::Str(self.label.clone())),
                ("metric", Json::Str(self.metric.clone())),
                ("commit", Json::Str(self.commit.clone())),
                ("timestamp", Json::Num(self.timestamp as f64)),
                ("value", Json::Num(self.value)),
                ("unit", Json::Str(self.unit.clone())),
                ("direction", Json::Str(self.direction.as_str().to_string())),
                ("p50", opt(self.p50)),
                ("p90", opt(self.p90)),
                ("p99", opt(self.p99)),
                ("mean", opt(self.mean)),
                ("iters", self.iters.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null)),
            ]),
        )])
    }

    fn from_json(j: &Json) -> Result<Sample> {
        let str_of = |k: &str| -> Result<String> {
            Ok(j.req(k)?.as_str().with_context(|| format!("'{k}' not a string"))?.to_string())
        };
        let opt = |k: &str| j.get(k).and_then(Json::as_f64);
        Ok(Sample {
            label: str_of("label")?,
            metric: str_of("metric")?,
            commit: str_of("commit")?,
            timestamp: j.req("timestamp")?.as_f64().context("'timestamp' not a number")? as u64,
            value: j.req("value")?.as_f64().context("'value' not a number")?,
            unit: str_of("unit")?,
            direction: Direction::parse(&str_of("direction")?)?,
            p50: opt("p50"),
            p90: opt("p90"),
            p99: opt("p99"),
            mean: opt("mean"),
            iters: j.get("iters").and_then(Json::as_f64).map(|v| v as u64),
        })
    }
}

/// A baseline-reset marker: the gate only considers samples recorded at
/// or after the newest bless whose scope matches their label.  Blessing
/// is how an *intentional* regression (a slower-but-correct rewrite, a
/// changed bench config) is accepted without deleting history.
#[derive(Debug, Clone, PartialEq)]
pub struct Bless {
    /// "" blesses every series; otherwise matches labels equal to the
    /// scope or nested under "<scope>/".
    pub scope: String,
    pub commit: String,
    pub timestamp: u64,
}

impl Bless {
    pub fn matches(&self, label: &str) -> bool {
        self.scope.is_empty()
            || label == self.scope
            || label
                .strip_prefix(&self.scope)
                .map(|rest| rest.starts_with('/'))
                .unwrap_or(false)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![(
            "bless",
            Json::obj(vec![
                ("scope", Json::Str(self.scope.clone())),
                ("commit", Json::Str(self.commit.clone())),
                ("timestamp", Json::Num(self.timestamp as f64)),
            ]),
        )])
    }

    fn from_json(j: &Json) -> Result<Bless> {
        Ok(Bless {
            scope: j.req("scope")?.as_str().context("'scope' not a string")?.to_string(),
            commit: j.req("commit")?.as_str().context("'commit' not a string")?.to_string(),
            timestamp: j.req("timestamp")?.as_f64().context("'timestamp' not a number")? as u64,
        })
    }
}

/// The persistent store: an in-memory view over the append-only log at
/// `path`.  `insert`/`bless` append to the file before mutating memory,
/// so a crash never loses acknowledged records.
#[derive(Debug)]
pub struct BenchDb {
    path: PathBuf,
    samples: Vec<Sample>,
    blesses: Vec<Bless>,
}

impl BenchDb {
    /// Open (creating if absent) the store at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<BenchDb> {
        let path = path.as_ref().to_path_buf();
        if !path.exists() {
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)
                        .with_context(|| format!("creating bench-db dir {parent:?}"))?;
                }
            }
            std::fs::write(&path, format!("{}\n", header_line()))
                .with_context(|| format!("creating bench db at {path:?}"))?;
            return Ok(BenchDb { path, samples: Vec::new(), blesses: Vec::new() });
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading bench db at {path:?}"))?;
        let mut lines = text.lines().enumerate();
        let (_, first) = lines.next().with_context(|| format!("bench db {path:?} is empty"))?;
        let header = Json::parse(first)
            .map_err(|e| anyhow::anyhow!("bench db {path:?} header: {e}"))?;
        let version = header.get("benchdb").and_then(Json::as_i64);
        if version != Some(DB_FORMAT_VERSION) {
            bail!(
                "bench db {path:?} has format version {version:?}, this build reads {DB_FORMAT_VERSION}"
            );
        }
        let mut samples = Vec::new();
        let mut blesses = Vec::new();
        let mut pending: Vec<(usize, &str)> =
            lines.filter(|(_, l)| !l.trim().is_empty()).collect();
        let last = pending.pop();
        for (ln, line) in pending {
            Self::parse_record(line, &mut samples, &mut blesses)
                .with_context(|| format!("bench db {path:?} line {}", ln + 1))?;
        }
        if let Some((ln, line)) = last {
            // a torn final line (writer crashed mid-append) is dropped, not fatal
            if Self::parse_record(line, &mut samples, &mut blesses).is_err() {
                eprintln!(
                    "[gcore] bench db {path:?}: dropping unparseable final record at line {} \
                     (torn append?)",
                    ln + 1
                );
            }
        }
        Ok(BenchDb { path, samples, blesses })
    }

    fn parse_record(line: &str, samples: &mut Vec<Sample>, blesses: &mut Vec<Bless>) -> Result<()> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(s) = j.get("sample") {
            samples.push(Sample::from_json(s)?);
        } else if let Some(b) = j.get("bless") {
            blesses.push(Bless::from_json(b)?);
        } else {
            bail!("record is neither a sample nor a bless");
        }
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    pub fn blesses(&self) -> &[Bless] {
        &self.blesses
    }

    fn append_line(&self, record: &Json) -> Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening bench db {:?} for append", self.path))?;
        writeln!(f, "{record}").with_context(|| format!("appending to bench db {:?}", self.path))
    }

    /// Insert one sample (durable before acknowledged).
    pub fn insert(&mut self, sample: Sample) -> Result<()> {
        self.append_line(&sample.to_json())?;
        self.samples.push(sample);
        Ok(())
    }

    /// Record a baseline reset for `scope` ("" = everything).
    pub fn bless(&mut self, scope: &str, commit: &str, timestamp: u64) -> Result<()> {
        let b = Bless { scope: scope.to_string(), commit: commit.to_string(), timestamp };
        self.append_line(&b.to_json())?;
        self.blesses.push(b);
        Ok(())
    }

    /// Distinct (label, metric) series, sorted.
    pub fn series_keys(&self) -> Vec<(String, String)> {
        let set: BTreeSet<(String, String)> = self
            .samples
            .iter()
            .map(|s| (s.label.clone(), s.metric.clone()))
            .collect();
        set.into_iter().collect()
    }

    /// Newest bless timestamp applying to `label` (0 when never blessed).
    pub fn bless_floor(&self, label: &str) -> u64 {
        self.blesses
            .iter()
            .filter(|b| b.matches(label))
            .map(|b| b.timestamp)
            .max()
            .unwrap_or(0)
    }

    /// One series, bless-filtered and time-ordered (stable on ties).
    pub fn series(&self, label: &str, metric: &str) -> Vec<&Sample> {
        let floor = self.bless_floor(label);
        let mut out: Vec<&Sample> = self
            .samples
            .iter()
            .filter(|s| s.label == label && s.metric == metric && s.timestamp >= floor)
            .collect();
        out.sort_by_key(|s| s.timestamp);
        out
    }

    /// Labels with at least one sample, sorted.
    pub fn labels(&self) -> Vec<String> {
        let set: BTreeSet<String> = self.samples.iter().map(|s| s.label.clone()).collect();
        set.into_iter().collect()
    }
}

fn header_line() -> String {
    Json::obj(vec![("benchdb", Json::Num(DB_FORMAT_VERSION as f64))]).to_string()
}

/// Median of the finite values in `xs` (None when empty after filtering).
pub fn median(xs: &[f64]) -> Option<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite values compare"));
    let n = v.len();
    Some(if n % 2 == 1 { v[n / 2] } else { (v[n / 2 - 1] + v[n / 2]) / 2.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gcore_benchdb_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn direction_inference_matches_table_headers() {
        use Direction::*;
        for (metric, want) in [
            ("tokens/s", HigherIsBetter),
            ("agg MB/s", HigherIsBetter),
            ("samples/h", HigherIsBetter),
            ("speedup ×", HigherIsBetter),
            ("util %", HigherIsBetter),
            ("live-slot util %", HigherIsBetter),
            ("shared hits", HigherIsBetter),
            ("GB/s", HigherIsBetter),
            ("ms/round", LowerIsBetter),
            ("stage-4 ms/step", LowerIsBetter),
            ("parse/compile ms", LowerIsBetter),
            ("client MB/round", LowerIsBetter),
            ("peak pages", LowerIsBetter),
            ("naive mean waste %", LowerIsBetter),
            ("bubble dev-s", LowerIsBetter),
            ("wall s", LowerIsBetter),
            ("comm s", LowerIsBetter),
            ("blocking ms", LowerIsBetter),
            ("waves", Informational),
            ("tokens", Informational),
            ("decode calls", Informational),
            ("cancelled", Informational),
            ("buckets", Informational),
        ] {
            assert_eq!(Direction::infer(metric, ""), want, "{metric}");
        }
        assert_eq!(Direction::infer("wall", "ns"), LowerIsBetter);
    }

    #[test]
    fn roundtrip_through_file() {
        let path = tmp("roundtrip");
        std::fs::remove_file(&path).ok();
        {
            let mut db = BenchDb::open(&path).unwrap();
            let mut s = Sample::scalar("e/x", "ms", "c1", 10, 1.5, "ms", Direction::LowerIsBetter);
            s.p50 = Some(1.4);
            s.p90 = Some(1.9);
            s.p99 = Some(2.5);
            s.mean = Some(1.55);
            s.iters = Some(100);
            db.insert(s.clone()).unwrap();
            db.bless("e", "c1", 11).unwrap();
            db.insert(Sample::scalar("e/x", "ms", "c2", 12, 1.6, "ms", Direction::LowerIsBetter))
                .unwrap();
        }
        let db = BenchDb::open(&path).unwrap();
        assert_eq!(db.len(), 2);
        assert_eq!(db.blesses().len(), 1);
        assert_eq!(db.samples()[0].p99, Some(2.5));
        assert_eq!(db.samples()[0].iters, Some(100));
        // bless at t=11 hides the t=10 sample from the series view
        let series = db.series("e/x", "ms");
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].commit, "c2");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_final_line_is_dropped() {
        let path = tmp("torn");
        std::fs::remove_file(&path).ok();
        {
            let mut db = BenchDb::open(&path).unwrap();
            db.insert(Sample::scalar("a", "m", "c1", 1, 2.0, "", Direction::LowerIsBetter))
                .unwrap();
        }
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"sample\": {{\"label\": \"a\", \"met").unwrap();
        drop(f);
        let db = BenchDb::open(&path).unwrap();
        assert_eq!(db.len(), 1, "torn append must not lose earlier records");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_middle_record_is_fatal() {
        let path = tmp("corrupt");
        std::fs::remove_file(&path).ok();
        let body = "{\"benchdb\": 1}\nnot json\n\
                    {\"bless\": {\"scope\": \"\", \"commit\": \"c\", \"timestamp\": 1}}\n";
        std::fs::write(&path, body).unwrap();
        assert!(BenchDb::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_is_fatal() {
        let path = tmp("version");
        std::fs::write(&path, "{\"benchdb\": 99}\n").unwrap();
        assert!(BenchDb::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bless_scope_matching() {
        let b = Bless { scope: "e8c".into(), commit: "c".into(), timestamp: 1 };
        assert!(b.matches("e8c"));
        assert!(b.matches("e8c/4/ring"));
        assert!(!b.matches("e8cx"));
        assert!(!b.matches("egen/16"));
        let all = Bless { scope: "".into(), commit: "c".into(), timestamp: 1 };
        assert!(all.matches("anything"));
    }

    #[test]
    fn median_math() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), Some(2.0));
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), Some(2.5));
        assert_eq!(median(&[f64::NAN, 5.0]), Some(5.0));
        assert_eq!(median(&[]), None);
        assert_eq!(median(&[f64::NAN]), None);
    }
}
