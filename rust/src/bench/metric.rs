//! Typed table cells.
//!
//! `experiments::Table` used to carry `rows: Vec<Vec<String>>` — every
//! measurement was formatted at the point of computation and the numbers
//! were gone.  `Metric` keeps the value, its display precision and its
//! unit together, so `to_markdown()`/`to_json()` become *renderers* over
//! typed data and the bench database (`bench::store`) can ingest the same
//! cells losslessly instead of re-parsing formatted strings.
//!
//! Rendering is pinned bit-identical to the legacy string cells: a
//! `Metric::f64(x, 3)` renders exactly what `format!("{x:.3}")` used to
//! produce, so the markdown/JSON output of every experiment table is
//! unchanged (modulo the versioned schema field on the JSON form).

use crate::util::bench::{fmt_bytes, fmt_dur};

/// One typed table cell: a value plus the unit and formatting it renders
/// with.  `render()`/`parse()` round-trip at the string level — see
/// `parse` for the exact guarantee.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    /// Floating-point measurement rendered at a fixed precision, with an
    /// optional display unit ("MB", "%", …) separated by one space.
    F64 {
        v: f64,
        prec: usize,
        unit: Option<String>,
    },
    /// Exact integer (counts, sizes-as-configured, world sizes, …).
    Int(i64),
    /// Byte count rendered human-readable ("512 B", "2.0 KiB", "3.00 MiB").
    Bytes(u64),
    /// Wall-clock duration rendered human-readable ("500 ns", "1.50 ms").
    DurationNs(u64),
    /// Free-form text (labels, placeholders like "-", composite summaries).
    Text(String),
    /// Boolean gates ("identical", "exactly-once", …).
    Bool(bool),
}

impl Metric {
    pub fn f64(v: f64, prec: usize) -> Metric {
        Metric::F64 { v, prec, unit: None }
    }

    pub fn f64_unit(v: f64, prec: usize, unit: &str) -> Metric {
        Metric::F64 { v, prec, unit: Some(unit.to_string()) }
    }

    pub fn int(v: i64) -> Metric {
        Metric::Int(v)
    }

    pub fn text(s: impl Into<String>) -> Metric {
        Metric::Text(s.into())
    }

    /// The string this cell displays as — the exact text the legacy
    /// stringly-typed rows carried.
    pub fn render(&self) -> String {
        match self {
            Metric::F64 { v, prec, unit: None } => format!("{v:.prec$}"),
            Metric::F64 { v, prec, unit: Some(u) } => format!("{v:.prec$} {u}"),
            Metric::Int(i) => i.to_string(),
            Metric::Bytes(b) => fmt_bytes(*b as usize),
            Metric::DurationNs(ns) => fmt_dur(std::time::Duration::from_nanos(*ns)),
            Metric::Text(s) => s.clone(),
            Metric::Bool(b) => (if *b { "true" } else { "false" }).to_string(),
        }
    }

    /// The numeric value this cell carries, if any — what the bench
    /// database stores.  Text and Bool cells are not measurements.
    pub fn value(&self) -> Option<f64> {
        match self {
            Metric::F64 { v, .. } => Some(*v),
            Metric::Int(i) => Some(*i as f64),
            Metric::Bytes(b) => Some(*b as f64),
            Metric::DurationNs(ns) => Some(*ns as f64),
            Metric::Text(_) | Metric::Bool(_) => None,
        }
    }

    /// The display unit, if the cell has one ("MB", "ns", …).
    pub fn unit_str(&self) -> Option<&str> {
        match self {
            Metric::F64 { unit, .. } => unit.as_deref(),
            Metric::Bytes(_) => Some("B"),
            Metric::DurationNs(_) => Some("ns"),
            _ => None,
        }
    }

    /// Best-effort inverse of `render` for ingesting legacy string cells
    /// (e.g. archived `BENCH_*.json` artifacts).  The guarantee is
    /// *render-level* identity — `Metric::parse(&m.render()).render() ==
    /// m.render()` for every cell an experiment table produces — not
    /// variant-level identity ("3.00 MiB" parses as an `F64` with unit
    /// "MiB", not as `Bytes`).
    pub fn parse(s: &str) -> Metric {
        match s {
            "true" => return Metric::Bool(true),
            "false" => return Metric::Bool(false),
            _ => {}
        }
        if let Some(m) = parse_number(s) {
            return m;
        }
        // "<number> <unit>": exactly two tokens, unit starts alphabetic-ish
        if let Some((num, unit)) = s.split_once(' ') {
            if unit_like(unit) {
                let parsed = match parse_number(num) {
                    Some(Metric::Int(i)) => {
                        Some(Metric::F64 { v: i as f64, prec: 0, unit: Some(unit.to_string()) })
                    }
                    Some(Metric::F64 { v, prec, .. }) => {
                        Some(Metric::F64 { v, prec, unit: Some(unit.to_string()) })
                    }
                    _ => None,
                };
                if let Some(m) = parsed {
                    return m;
                }
            }
        }
        Metric::Text(s.to_string())
    }
}

/// Parse a bare fixed-point number, rejecting anything whose re-rendering
/// would differ from the input (leading zeros, exponents, …).
fn parse_number(s: &str) -> Option<Metric> {
    let body = s.strip_prefix('-').unwrap_or(s);
    if body.is_empty() || !body.as_bytes()[0].is_ascii_digit() {
        return None;
    }
    if let Some((int_part, frac)) = body.split_once('.') {
        if int_part.is_empty()
            || frac.is_empty()
            || !int_part.bytes().all(|b| b.is_ascii_digit())
            || !frac.bytes().all(|b| b.is_ascii_digit())
        {
            return None;
        }
        let v: f64 = s.parse().ok()?;
        let prec = frac.len();
        if format!("{v:.prec$}") == s {
            return Some(Metric::F64 { v, prec, unit: None });
        }
        return None;
    }
    if !body.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    if let Ok(i) = s.parse::<i64>() {
        if i.to_string() == s {
            return Some(Metric::Int(i));
        }
    }
    // integers beyond i64 (or with leading zeros): keep only if the f64
    // re-render is exact
    let v: f64 = s.parse().ok()?;
    if format!("{v:.0}") == s {
        return Some(Metric::F64 { v, prec: 0, unit: None });
    }
    None
}

/// A display unit is a single short token starting with a letter (or one
/// of the symbols our formatters emit) — "MB", "µs", "%", "×" — never a
/// phrase ("train step (tiny)").
fn unit_like(u: &str) -> bool {
    !u.is_empty()
        && u.len() <= 12
        && !u.contains(' ')
        && u.chars()
            .next()
            .map(|c| c.is_alphabetic() || matches!(c, '×' | 'µ' | '%'))
            .unwrap_or(false)
}

impl From<&str> for Metric {
    fn from(s: &str) -> Metric {
        Metric::Text(s.to_string())
    }
}
impl From<String> for Metric {
    fn from(s: String) -> Metric {
        Metric::Text(s)
    }
}
impl From<bool> for Metric {
    fn from(b: bool) -> Metric {
        Metric::Bool(b)
    }
}
impl From<i64> for Metric {
    fn from(v: i64) -> Metric {
        Metric::Int(v)
    }
}
impl From<i32> for Metric {
    fn from(v: i32) -> Metric {
        Metric::Int(v as i64)
    }
}
impl From<u32> for Metric {
    fn from(v: u32) -> Metric {
        Metric::Int(v as i64)
    }
}
impl From<u64> for Metric {
    fn from(v: u64) -> Metric {
        Metric::Int(v as i64)
    }
}
impl From<usize> for Metric {
    fn from(v: usize) -> Metric {
        Metric::Int(v as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_matches_legacy_formatting() {
        assert_eq!(Metric::f64(0.1234, 3).render(), format!("{:.3}", 0.1234));
        assert_eq!(Metric::f64(120.0, 0).render(), "120");
        assert_eq!(Metric::f64_unit(4.19, 2, "MB").render(), "4.19 MB");
        assert_eq!(Metric::int(-7).render(), "-7");
        assert_eq!(Metric::Bool(true).render(), "true");
        assert_eq!(Metric::text("-").render(), "-");
        assert_eq!(Metric::Bytes(2048).render(), "2.0 KiB");
        assert_eq!(Metric::DurationNs(500).render(), "500 ns");
    }

    #[test]
    fn parse_render_identity_on_typical_cells() {
        for s in [
            "true", "false", "-", "?", "OOM", "0", "42", "-3", "0.123", "-0.00", "1.20",
            "4.19 MB", "512 B", "2.0 KiB", "98.7", "co-locate", "σ=0.7, 8 ranks × 32/rank",
            "1 (capped)", "— summary —", "2b + cancel", "1 train step (tiny)", "200/200",
            "dyn makespan 123s", "100000000000000000000", "NaN", "1e9", "007",
        ] {
            assert_eq!(Metric::parse(s).render(), s, "round-trip broke on {s:?}");
        }
    }

    #[test]
    fn parse_recovers_values_and_units() {
        assert_eq!(Metric::parse("4.19 MB").value(), Some(4.19));
        assert_eq!(Metric::parse("4.19 MB").unit_str(), Some("MB"));
        assert_eq!(Metric::parse("42").value(), Some(42.0));
        assert_eq!(Metric::parse("true"), Metric::Bool(true));
        assert_eq!(Metric::parse("n/a").value(), None);
        // phrases never parse as numbers
        assert!(matches!(Metric::parse("1 train step (tiny)"), Metric::Text(_)));
    }

    #[test]
    fn text_and_bool_carry_no_value() {
        assert_eq!(Metric::text("x").value(), None);
        assert_eq!(Metric::Bool(false).value(), None);
        assert_eq!(Metric::f64(1.5, 1).value(), Some(1.5));
    }
}
