//! The regression gate: CI's enforcement layer over the bench store.
//!
//! For every directed series the gate compares the current commit's
//! median against the rolling median of the last `window` *distinct
//! prior commits* (each prior commit contributes its own per-commit
//! median first, so a commit that ran the bench three times doesn't get
//! three votes).  A series regresses when it moves in its bad direction
//! by more than `threshold_pct` percent; any regressed series fails the
//! gate (`gcore bench gate` exits nonzero).  First-commit bootstrap and
//! informational series always pass.

use super::store::{median, BenchDb, Direction, Sample};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within threshold (or an improvement).
    Pass,
    /// Moved > threshold in the bad direction — fails the gate.
    Fail,
    /// No prior commits to compare against (first run of a series).
    Bootstrap,
    /// Not comparable: informational direction, or a ~0 baseline.
    Skipped,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Fail => "FAIL",
            Verdict::Bootstrap => "bootstrap",
            Verdict::Skipped => "skip",
        }
    }
}

/// Per-series gate outcome.
#[derive(Debug, Clone)]
pub struct SeriesVerdict {
    pub label: String,
    pub metric: String,
    pub direction: Direction,
    /// Median of the current commit's samples.
    pub current: f64,
    /// Rolling median of the prior-commit medians (None on bootstrap).
    pub baseline: Option<f64>,
    /// Percent moved in the bad direction (negative = improved).
    pub regression_pct: Option<f64>,
    /// How many prior commits the baseline covered (≤ window).
    pub baseline_commits: usize,
    pub verdict: Verdict,
}

#[derive(Debug, Clone)]
pub struct GateReport {
    pub commit: String,
    pub threshold_pct: f64,
    pub window: usize,
    pub series: Vec<SeriesVerdict>,
}

impl GateReport {
    pub fn failures(&self) -> Vec<&SeriesVerdict> {
        self.series.iter().filter(|s| s.verdict == Verdict::Fail).collect()
    }

    pub fn passed(&self) -> bool {
        self.failures().is_empty()
    }
}

/// Per-commit medians of `series`, oldest commit first.  Commit order is
/// the order of each commit's first appearance in the time-sorted series
/// (timestamps tie-break within CI runs that share a clock second).
fn commit_medians(series: &[&Sample]) -> Vec<(String, f64)> {
    let mut order: Vec<String> = Vec::new();
    for s in series {
        if !order.contains(&s.commit) {
            order.push(s.commit.clone());
        }
    }
    order
        .into_iter()
        .filter_map(|c| {
            let vals: Vec<f64> =
                series.iter().filter(|s| s.commit == c).map(|s| s.value).collect();
            median(&vals).map(|m| (c, m))
        })
        .collect()
}

/// How far `current` moved past `baseline` in the bad direction, in
/// percent.  Positive = regressed, negative = improved.
pub fn regression_pct(direction: Direction, baseline: f64, current: f64) -> Option<f64> {
    if baseline.abs() < 1e-12 {
        return None;
    }
    match direction {
        Direction::LowerIsBetter => Some((current - baseline) / baseline.abs() * 100.0),
        Direction::HigherIsBetter => Some((baseline - current) / baseline.abs() * 100.0),
        Direction::Informational => None,
    }
}

/// Gate every directed series that has samples for `commit`.
pub fn gate(db: &BenchDb, commit: &str, threshold_pct: f64, window: usize) -> GateReport {
    let window = window.max(1);
    let mut out = Vec::new();
    for (label, metric) in db.series_keys() {
        let series = db.series(&label, &metric);
        let cur_vals: Vec<f64> =
            series.iter().filter(|s| s.commit == commit).map(|s| s.value).collect();
        let Some(current) = median(&cur_vals) else {
            continue; // series has no samples for this commit — nothing to judge
        };
        let direction = series
            .iter()
            .find(|s| s.commit == commit)
            .map(|s| s.direction)
            .unwrap_or(Direction::Informational);
        if direction == Direction::Informational {
            out.push(SeriesVerdict {
                label,
                metric,
                direction,
                current,
                baseline: None,
                regression_pct: None,
                baseline_commits: 0,
                verdict: Verdict::Skipped,
            });
            continue;
        }
        let prior: Vec<&Sample> =
            series.iter().filter(|s| s.commit != commit).copied().collect();
        let per_commit = commit_medians(&prior);
        if per_commit.is_empty() {
            out.push(SeriesVerdict {
                label,
                metric,
                direction,
                current,
                baseline: None,
                regression_pct: None,
                baseline_commits: 0,
                verdict: Verdict::Bootstrap,
            });
            continue;
        }
        let tail: Vec<f64> = per_commit
            .iter()
            .rev()
            .take(window)
            .map(|(_, m)| *m)
            .collect();
        let baseline_commits = tail.len();
        let baseline = median(&tail).expect("non-empty tail has a median");
        let reg = regression_pct(direction, baseline, current);
        let verdict = match reg {
            // +1e-9 absorbs float noise exactly at the threshold boundary
            Some(r) if r > threshold_pct + 1e-9 => Verdict::Fail,
            Some(_) => Verdict::Pass,
            None => Verdict::Skipped,
        };
        out.push(SeriesVerdict {
            label,
            metric,
            direction,
            current,
            baseline: Some(baseline),
            regression_pct: reg,
            baseline_commits,
            verdict,
        });
    }
    GateReport {
        commit: commit.to_string(),
        threshold_pct,
        window,
        series: out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gcore_gate_{}_{name}.jsonl", std::process::id()))
    }

    fn db_with(name: &str, points: &[(&str, u64, f64)]) -> BenchDb {
        let path = tmp(name);
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        for (commit, ts, v) in points {
            db.insert(Sample::scalar(
                "e/x",
                "ms",
                *commit,
                *ts,
                *v,
                "ms",
                Direction::LowerIsBetter,
            ))
            .unwrap();
        }
        std::fs::remove_file(&path).ok(); // in-memory view survives unlink
        db
    }

    #[test]
    fn bootstrap_passes() {
        let db = db_with("boot", &[("c1", 1, 10.0)]);
        let r = gate(&db, "c1", 20.0, 5);
        assert_eq!(r.series.len(), 1);
        assert_eq!(r.series[0].verdict, Verdict::Bootstrap);
        assert!(r.passed());
    }

    #[test]
    fn unchanged_passes_and_regression_fails() {
        let base: Vec<(&str, u64, f64)> =
            vec![("c1", 1, 10.0), ("c2", 2, 10.2), ("c3", 3, 9.9)];
        // unchanged
        let mut pts = base.clone();
        pts.push(("c4", 4, 10.0));
        let r = gate(&db_with("same", &pts), "c4", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Pass);
        // +50% on a lower-is-better metric
        let mut pts = base.clone();
        pts.push(("c4", 4, 15.0));
        let r = gate(&db_with("reg", &pts), "c4", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Fail);
        assert!(!r.passed());
        assert_eq!(r.failures().len(), 1);
        // -30% (an improvement) passes
        let mut pts = base;
        pts.push(("c4", 4, 7.0));
        let r = gate(&db_with("imp", &pts), "c4", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Pass);
        assert!(r.series[0].regression_pct.unwrap() < 0.0);
    }

    #[test]
    fn injected_pct_fails_iff_above_threshold() {
        // baseline median of {10, 10, 10} = 10; inject +X%
        for (x, should_fail) in
            [(0.0, false), (5.0, false), (19.0, false), (21.0, true), (50.0, true)]
        {
            let pts = vec![
                ("c1", 1, 10.0),
                ("c2", 2, 10.0),
                ("c3", 3, 10.0),
                ("c4", 4, 10.0 * (1.0 + x / 100.0)),
            ];
            let r = gate(&db_with(&format!("inj{}", x as i64), &pts), "c4", 20.0, 5);
            assert_eq!(
                r.series[0].verdict,
                if should_fail { Verdict::Fail } else { Verdict::Pass },
                "+{x}%"
            );
        }
    }

    #[test]
    fn window_only_sees_last_k_commits() {
        // old commits were fast (1.0); the last 3 settled at 10.0.  With
        // window=3 the baseline is 10.0, so 10.5 passes; with window=50
        // the baseline median over {1,1,1,10,10,10} straddles — make it
        // odd so the wide window flags what the narrow one accepts.
        let pts = vec![
            ("c1", 1, 1.0),
            ("c2", 2, 1.0),
            ("c3", 3, 1.0),
            ("c4", 4, 10.0),
            ("c5", 5, 10.0),
            ("c6", 6, 10.0),
            ("c7", 7, 10.5),
        ];
        let narrow = gate(&db_with("win_n", &pts), "c7", 20.0, 3);
        assert_eq!(narrow.series[0].verdict, Verdict::Pass);
        assert_eq!(narrow.series[0].baseline, Some(10.0));
        assert_eq!(narrow.series[0].baseline_commits, 3);
        let wide = gate(&db_with("win_w", &pts), "c7", 20.0, 50);
        assert_eq!(wide.series[0].baseline, Some(5.5));
        assert_eq!(wide.series[0].baseline_commits, 6);
        assert_eq!(wide.series[0].verdict, Verdict::Fail);
    }

    #[test]
    fn fewer_than_window_commits_still_gates() {
        let pts = vec![("c1", 1, 10.0), ("c2", 2, 20.0)];
        let r = gate(&db_with("short", &pts), "c2", 20.0, 5);
        assert_eq!(r.series[0].baseline_commits, 1);
        assert_eq!(r.series[0].verdict, Verdict::Fail);
    }

    #[test]
    fn repeated_runs_of_one_commit_get_one_vote() {
        // c1 ran 3× (9, 10, 11 → median 10), c2 once at 30: clear fail,
        // and the baseline is the per-commit median, not the sample pool.
        let pts = vec![("c1", 1, 9.0), ("c1", 2, 11.0), ("c1", 3, 10.0), ("c2", 4, 30.0)];
        let r = gate(&db_with("mult", &pts), "c2", 20.0, 5);
        assert_eq!(r.series[0].baseline, Some(10.0));
        assert_eq!(r.series[0].baseline_commits, 1);
        assert_eq!(r.series[0].verdict, Verdict::Fail);
    }

    #[test]
    fn higher_is_better_inverts() {
        let path = tmp("higher");
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        for (c, ts, v) in [("c1", 1u64, 100.0), ("c2", 2, 100.0), ("c3", 3, 70.0)] {
            db.insert(Sample::scalar(
                "e/t",
                "tokens/s",
                c,
                ts,
                v,
                "",
                Direction::HigherIsBetter,
            ))
            .unwrap();
        }
        let r = gate(&db, "c3", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Fail, "throughput drop must fail");
        let r = gate(&db, "c2", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Pass);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn informational_series_never_gate() {
        let path = tmp("info");
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        for (c, ts, v) in [("c1", 1u64, 5.0), ("c2", 2, 500.0)] {
            db.insert(Sample::scalar("e/w", "waves", c, ts, v, "", Direction::Informational))
                .unwrap();
        }
        let r = gate(&db, "c2", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Skipped);
        assert!(r.passed());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bless_resets_the_baseline() {
        let path = tmp("bless");
        std::fs::remove_file(&path).ok();
        let mut db = BenchDb::open(&path).unwrap();
        let mut put = |c: &str, ts: u64, v: f64| {
            db.insert(Sample::scalar("e/x", "ms", c, ts, v, "ms", Direction::LowerIsBetter))
                .unwrap();
        };
        put("c1", 1, 10.0);
        put("c2", 2, 10.0);
        put("c3", 3, 30.0); // intentional 3× slowdown
        let r = gate(&db, "c3", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Fail);
        db.bless("e/x", "c3", 3).unwrap();
        // post-bless: c3 is the only visible history, so c3 re-gates as
        // bootstrap and c4 gates against the new 30.0 baseline
        let r = gate(&db, "c3", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Bootstrap);
        db.insert(Sample::scalar("e/x", "ms", "c4", 4, 31.0, "ms", Direction::LowerIsBetter))
            .unwrap();
        let r = gate(&db, "c4", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Pass);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zero_baseline_is_skipped_not_divided() {
        let pts = vec![("c1", 1, 0.0), ("c2", 2, 5.0)];
        let r = gate(&db_with("zero", &pts), "c2", 20.0, 5);
        assert_eq!(r.series[0].verdict, Verdict::Skipped);
        assert!(r.passed());
    }

    #[test]
    fn regression_pct_math() {
        use Direction::*;
        let close = |got: Option<f64>, want: f64| (got.unwrap() - want).abs() < 1e-9;
        assert!(close(regression_pct(LowerIsBetter, 10.0, 12.0), 20.0));
        assert!(close(regression_pct(HigherIsBetter, 10.0, 8.0), 20.0));
        assert!(close(regression_pct(LowerIsBetter, 10.0, 8.0), -20.0));
        assert!(close(regression_pct(HigherIsBetter, -10.0, -12.0), 20.0));
        assert_eq!(regression_pct(Informational, 10.0, 99.0), None);
        assert_eq!(regression_pct(LowerIsBetter, 0.0, 5.0), None);
    }
}
