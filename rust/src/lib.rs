//! # G-Core (reproduction)
//!
//! A from-scratch reproduction of *G-Core: A Simple, Scalable and Balanced
//! RLHF Trainer* (Wu et al., Tencent, 2025) as a three-layer Rust + JAX +
//! Pallas system: this crate is Layer 3 (the coordinator — the paper's
//! system contribution), executing Layer-2 JAX models and the Layer-1
//! Pallas attention kernel through AOT-compiled HLO artifacts via PJRT.
//!
//! See DESIGN.md for the full system inventory and experiment index, and
//! EXPERIMENTS.md for reproduced results.

// Unsafe inventory: `util::pod` is the only module with unsafe *code*
// (POD slice reinterpretation for the collective data plane); the pjrt
// feature adds two `unsafe impl Send/Sync` in `runtime::engine` justified
// by its backend mutex.  Keep it that way — new unsafe belongs in
// util::pod behind a safe API, and any unsafe fn must spell out its
// internal unsafe blocks:
#![deny(unsafe_op_in_unsafe_fn)]

pub mod attention;
pub mod balance;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod launch;
pub mod metrics;
pub mod placement;
pub mod reward;
pub mod rpc;
pub mod storage;
pub mod runtime;
pub mod util;
