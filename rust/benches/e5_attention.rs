//! E5 bench: context-parallel attention feasibility table + the Pallas
//! attention micro-artifact timing (interpret-mode CPU; structure-level
//! perf estimates live in the manifest).
use gcore::runtime::{Engine, Tensor};
use gcore::util::bench;

fn main() {
    gcore::experiments::e5_attention(false).print();
    if let Ok(e) = Engine::load("tiny") {
        let d = e.manifest().dims.clone();
        let n = d.batch * d.n_heads * d.max_seq * d.d_head();
        let mk = |s: usize| {
            Tensor::f32(
                vec![d.batch, d.n_heads, d.max_seq, d.d_head()],
                (0..n).map(|i| ((i + s) % 13) as f32 / 13.0).collect(),
            )
        };
        let (q, k, v) = (mk(0), mk(3), mk(7));
        e.run("attn_micro", &[q.clone(), k.clone(), v.clone()]).unwrap();
        let r = bench::bench_n("attn_micro (pallas interpret, tiny)", 20, || {
            bench::black_box(e.run("attn_micro", &[q.clone(), k.clone(), v.clone()]).unwrap());
        });
        bench::print_table("E5 kernel micro (CPU interpret — not a TPU proxy)", &[r]);
    }
}
