//! E4 bench: workload-balancing waste + balancer hot-path timing (§4.4).
use gcore::balance::{assign_balanced, assign_naive};
use gcore::cluster::workload::GenLenModel;
use gcore::util::bench;
use gcore::util::rng::Rng;

fn main() {
    gcore::experiments::e4_balance(false).print();
    // hot path: assignment of one 1024-seq global batch across 32 ranks
    let glm = GenLenModel::reasoning_default();
    let mut rng = Rng::new(1);
    let lens = glm.sample_batch(&mut rng, 0, 1024);
    let costs: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
    let batch: Vec<usize> = (0..1024).collect();
    let results = vec![
        bench::bench("assign_naive 1024/32", 50, std::time::Duration::from_millis(300), || {
            bench::black_box(assign_naive(&batch, 32, &mut rng));
        }),
        bench::bench("assign_balanced 1024/32", 50, std::time::Duration::from_millis(300), || {
            bench::black_box(assign_balanced(&batch, &costs, 32));
        }),
    ];
    bench::print_table("E4 balancer hot path", &results);
}
