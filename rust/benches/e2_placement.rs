//! E2 bench: placement strategies under plain GRPO vs dynamic sampling.
use gcore::placement::{run_colocate, run_dynamic, PlacementSpec};
use gcore::util::bench;

fn main() {
    let t = gcore::experiments::e2_placement(false);
    t.print();
    let spec = PlacementSpec::paper_like();
    let results = vec![
        bench::bench_n("sim colocate 64dev x20steps", 10, || {
            bench::black_box(run_colocate(&spec));
        }),
        bench::bench_n("sim dynamic 64dev x20steps", 10, || {
            bench::black_box(run_dynamic(&spec));
        }),
    ];
    bench::print_table("E2 simulator throughput", &results);
}
