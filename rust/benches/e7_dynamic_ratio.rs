//! E7 bench: dynamic placement ratio adaptation under length drift.
fn main() {
    gcore::experiments::e7_dynamic_ratio(false).print();
}
