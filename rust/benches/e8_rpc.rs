//! E8 bench: exactly-once RPC under fault injection + transport latency.
use std::sync::Arc;
use gcore::rpc::client::RpcClient;
use gcore::rpc::server::RpcServer;
use gcore::rpc::transport::{InProcTransport, TcpRpcHost, TcpTransport};
use gcore::util::bench;

fn main() {
    gcore::experiments::e8_rpc(false).print();
    gcore::experiments::e8_collective(false).print();
    // transport latency micro
    let server = Arc::new(RpcServer::new(|_: &str, p: &[u8]| Ok(p.to_vec())));
    let inproc = RpcClient::new(InProcTransport::new(server.clone()));
    let host = TcpRpcHost::spawn(server.clone()).unwrap();
    let tcp = RpcClient::new(TcpTransport::connect(host.addr));
    let payload = vec![0u8; 4096];
    let results = vec![
        bench::bench("inproc 4KB call", 100, std::time::Duration::from_millis(400), || {
            bench::black_box(inproc.call("echo", payload.clone()).unwrap());
        }),
        bench::bench("tcp 4KB call", 100, std::time::Duration::from_millis(400), || {
            bench::black_box(tcp.call("echo", payload.clone()).unwrap());
        }),
    ];
    bench::print_table("E8 RPC latency", &results);
}
