//! §Perf microbench (EXPERIMENTS.md): the production `generate` entry
//! (fused `generate_rollout` when the set carries it, the continuous-
//! batching scheduler otherwise) vs the stepwise reference decoder, per
//! artifact set.
use std::sync::Arc;
use gcore::coordinator::generation::{self, generate, SamplerConfig};
use gcore::data::tasks::{TaskGen, TaskKind};
use gcore::runtime::{init_policy, Engine};
use gcore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    for set in ["tiny", "quickstart"] {
        let Ok(e) = Engine::load(set) else {
            eprintln!("skipping {set}: artifacts not built");
            continue;
        };
        let e = Arc::new(e);
        let d = e.manifest().dims.clone();
        let params = init_policy(&e, 0)?;
        let mut tg = TaskGen::new(vec![TaskKind::Copy], 1);
        let prompts: Vec<Vec<i32>> = tg
            .sample_n(d.batch)
            .iter()
            .map(|t| t.prompt_tokens(d.prompt_len).unwrap())
            .collect();
        let mut rng = Rng::new(2);
        // the manifest's baked sampler params (or the defaults) keep the
        // production lane on its fast path; the reference lane calls the
        // stepwise decoder directly instead of spoofing a config mismatch
        let cfg = match e.manifest().sampler {
            Some(b) => SamplerConfig { top_k: b.top_k, stop_at_eos: b.stop_at_eos, ..SamplerConfig::default() },
            None => SamplerConfig::default(),
        };
        let prod_label = if e.manifest().artifacts.contains_key("generate_rollout") {
            "fused"
        } else {
            "scheduled"
        };
        generate(&e, &params, &prompts, &cfg, &mut rng)?; // compile
        generation::generate_stepwise(&e, &params, &prompts, &cfg, &mut rng)?;
        type GenFn = fn(
            &Engine,
            &gcore::runtime::ParamSet,
            &[Vec<i32>],
            &SamplerConfig,
            &mut Rng,
        ) -> anyhow::Result<generation::GenOutput>;
        let lanes: [(&str, GenFn); 2] =
            [(prod_label, generate), ("stepwise", generation::generate_stepwise)];
        for (label, f) in lanes {
            let t0 = std::time::Instant::now();
            let n = 8;
            for _ in 0..n {
                std::hint::black_box(f(&e, &params, &prompts, &cfg, &mut rng)?);
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            println!(
                "{set:>10} {label:>9}: {:6.1} ms/rollout ({} seqs × {} gen tokens)",
                per * 1e3,
                d.batch,
                d.gen_len()
            );
        }
    }
    Ok(())
}
