//! §Perf microbench (EXPERIMENTS.md): fused `generate_rollout` vs the
//! per-token `prefill`/`decode_step` generation path, per artifact set.
use std::sync::Arc;
use gcore::coordinator::generation::{generate, SamplerConfig};
use gcore::data::tasks::{TaskGen, TaskKind};
use gcore::runtime::{init_policy, Engine};
use gcore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    for set in ["tiny", "quickstart"] {
        let Ok(e) = Engine::load(set) else {
            eprintln!("skipping {set}: artifacts not built");
            continue;
        };
        let e = Arc::new(e);
        let d = e.manifest().dims.clone();
        let params = init_policy(&e, 0)?;
        let mut tg = TaskGen::new(vec![TaskKind::Copy], 1);
        let prompts: Vec<Vec<i32>> = tg
            .sample_n(d.batch)
            .iter()
            .map(|t| t.prompt_tokens(d.prompt_len).unwrap())
            .collect();
        let mut rng = Rng::new(2);
        let fused_cfg = SamplerConfig::default(); // top_k 16 → fused path
        let step_cfg = SamplerConfig { top_k: 15, ..SamplerConfig::default() };
        generate(&e, &params, &prompts, &fused_cfg, &mut rng)?; // compile
        generate(&e, &params, &prompts, &step_cfg, &mut rng)?;
        for (label, cfg) in [("fused", &fused_cfg), ("stepwise", &step_cfg)] {
            let t0 = std::time::Instant::now();
            let n = 8;
            for _ in 0..n {
                std::hint::black_box(generate(&e, &params, &prompts, cfg, &mut rng)?);
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            println!(
                "{set:>10} {label:>9}: {:6.1} ms/rollout ({} seqs × {} gen tokens)",
                per * 1e3,
                d.batch,
                d.gen_len()
            );
        }
    }
    Ok(())
}
