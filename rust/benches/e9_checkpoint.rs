//! E9 bench: checkpointing latency + elasticity.
fn main() {
    gcore::experiments::e9_checkpoint(false).print();
}
