//! E1 bench: controller data-plane scaling (paper §3.1, Fig. 1).
//! Regenerates the E1 table and times the routing hot path.
use gcore::coordinator::single::{route_parallel, route_single};
use gcore::data::payload::PayloadSpec;
use gcore::util::bench;

fn main() {
    let t = gcore::experiments::e1_controller_scaling(true);
    t.print();
    // timing: per-configuration routing wallclock
    let spec = PayloadSpec::paper_2k().scaled(32);
    let mut results = Vec::new();
    results.push(bench::bench_n("route_single x16", 5, || {
        bench::black_box(route_single(&spec, 16, usize::MAX, 1).unwrap());
    }));
    for n in [2usize, 4, 8] {
        results.push(bench::bench_n(&format!("route_parallel x16/{n}"), 5, || {
            bench::black_box(route_parallel(&spec, 16, n, 1).unwrap());
        }));
    }
    bench::print_table("E1 routing latency", &results);
}
