//! E3 bench: long-tail amplification table.
fn main() {
    gcore::experiments::e3_longtail(false).print();
}
