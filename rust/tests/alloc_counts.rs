//! Allocation-count assertions for the zero-copy collective data plane,
//! via a counting global allocator.  The gradient hot path's slice ops
//! (`ReduceOp::combine`, `decode_param_flat_into`, `Tensor::add_assign`)
//! must not allocate at all, and `encode_param_flat` must allocate exactly
//! its one output buffer.  An engine-gated check bounds the stepwise
//! decode loop's allocations to O(step outputs) — the old loop cloned the
//! full `ParamSet` every token.
//!
//! Everything runs in ONE test function: the counters are process-global,
//! so concurrent test threads (even just libtest spawning them) would
//! pollute the deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use gcore::coordinator::collective::{decode_param_flat_into, encode_param_flat, ReduceOp};
use gcore::runtime::{ParamSet, Tensor};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn counting<R>(f: impl FnOnce() -> R) -> (u64, u64, R) {
    let calls0 = ALLOC_CALLS.load(Ordering::SeqCst);
    let bytes0 = ALLOC_BYTES.load(Ordering::SeqCst);
    let out = f();
    (
        ALLOC_CALLS.load(Ordering::SeqCst) - calls0,
        ALLOC_BYTES.load(Ordering::SeqCst) - bytes0,
        out,
    )
}

fn reduce_hot_path_does_not_allocate() {
    let n = 1 << 16;
    let set = ParamSet::new(vec![
        Tensor::f32(vec![n], (0..n).map(|i| i as f32 * 0.5 - 7.0).collect()),
        Tensor::f32(vec![n / 2], (0..n / 2).map(|i| 1.0 - i as f32).collect()),
    ]);
    let flat = encode_param_flat(&set).unwrap();
    let mut acc = flat.clone();
    let mut out = set.clone();
    let other = set.clone();
    let mut target = set.clone();

    // combine: the elementwise fold every reduce round runs per chunk
    let (calls, _, _) = counting(|| ReduceOp::SumF32.combine(&mut acc, &flat).unwrap());
    assert_eq!(calls, 0, "ReduceOp::combine must not allocate");

    // decode into the existing gradient set
    let (calls, _, _) = counting(|| decode_param_flat_into(&flat, &mut out).unwrap());
    assert_eq!(calls, 0, "decode_param_flat_into must not allocate");

    // add_assign no longer copies its right-hand side
    let (calls, _, _) = counting(|| {
        for (a, b) in target.tensors.iter_mut().zip(&other.tensors) {
            a.add_assign(b).unwrap();
        }
    });
    assert_eq!(calls, 0, "Tensor::add_assign must not allocate");

    // encode allocates exactly its output buffer (with_capacity, no growth)
    let (calls, bytes, encoded) = counting(|| encode_param_flat(&set).unwrap());
    assert!(calls <= 1, "encode_param_flat allocated {calls} times");
    assert!(
        bytes <= (set.num_elements() * 4 + 64) as u64,
        "encode_param_flat over-allocated: {bytes} bytes"
    );
    assert_eq!(encoded.len(), set.num_elements() * 4);
}

fn combine_throughput_report() {
    // not a perf gate — just proof the fast path processes a multi-MB
    // buffer as slices (and a throughput figure for the log)
    let n = 1 << 20;
    let vals: Vec<f32> = (0..n).map(|i| (i % 1024) as f32 * 1e-3).collect();
    let set = ParamSet::new(vec![Tensor::f32(vec![n], vals)]);
    let flat = encode_param_flat(&set).unwrap();
    let mut acc = flat.clone();
    let t0 = std::time::Instant::now();
    let reps = 8;
    for _ in 0..reps {
        ReduceOp::SumF32.combine(&mut acc, &flat).unwrap();
    }
    let mbps = (flat.len() * reps) as f64 / 1e6 / t0.elapsed().as_secs_f64();
    println!(
        "combine throughput: {mbps:.0} MB/s over {} MB",
        flat.len() / 1_000_000
    );
    assert!(mbps > 0.0);
}

fn stepwise_decode_allocations_bounded_by_step_outputs() {
    // Engine-gated, and since the interpreter backend landed it actually
    // RUNS on default builds (against the checked-in fixture artifacts):
    // the stepwise decode loop borrows the params now, so its allocations
    // are bounded by the per-step engine outputs — reintroducing the
    // per-token `ParamSet` clone would blow well past this bound.
    let engine = gcore::runtime::Engine::try_load("tiny").unwrap_or_else(|| {
        panic!(
            "tiny artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        )
    });
    use gcore::coordinator::generation::{generate, SamplerConfig};
    use gcore::data::tasks::{TaskGen, TaskKind};
    let dims = engine.manifest().dims.clone();
    let params = gcore::runtime::init_policy(&engine, 3).unwrap();
    let mut tg = TaskGen::new(vec![TaskKind::Copy], 5);
    let prompts: Vec<Vec<i32>> = tg
        .sample_n(dims.batch)
        .iter()
        .map(|t| t.prompt_tokens(dims.prompt_len).unwrap())
        .collect();
    // greedy top-1 forces the stepwise path; first call compiles/warms up
    let cfg = SamplerConfig { temperature: 0.0, top_k: 1, stop_at_eos: false };
    let mut rng = gcore::util::rng::Rng::new(7);
    generate(&engine, &params, &prompts, &cfg, &mut rng).unwrap();

    let decode_steps = (dims.max_seq - dims.prompt_len) as u64;
    let t0 = std::time::Instant::now();
    let (_, bytes, out) =
        counting(|| generate(&engine, &params, &prompts, &cfg, &mut rng).unwrap());
    let toks = out.gen_lens.iter().sum::<usize>() as f64;
    println!(
        "stepwise decode: {:.0} tok/s, {bytes} bytes allocated over {decode_steps} steps",
        toks / t0.elapsed().as_secs_f64(),
    );
    // per-step outputs: logits [B,V] + the KV caches the decode_step
    // artifact returns; a per-token param clone would add
    // params.size_bytes() on top of this for every step
    let step_out_bytes: u64 = engine
        .manifest()
        .artifact("decode_step")
        .unwrap()
        .outputs
        .iter()
        .map(|o| o.shape.iter().product::<usize>() as u64 * 4)
        .sum();
    let bound = (decode_steps + 2) * (8 * step_out_bytes + (1 << 20));
    assert!(
        bytes < bound,
        "stepwise decode allocated {bytes} bytes (> bound {bound}); \
         did a per-token ParamSet clone creep back in?"
    );

    // Interpreter-specific pin, tighter than the generic bound above: one
    // decode_step evaluation allocates at most the engine-boundary input
    // copies (params + caches ≈ 0.8 MB at tiny scale) plus the sum of its
    // live instruction outputs (≤ 1.5 MB — cache slices/concats dominate;
    // reshape/convert are Arc-zero-copy and elementwise ops mutate taken
    // buffers in place).  3 MB/token of budget catches any regression of
    // the buffer-reuse machinery (last-use take + in-place
    // dynamic-update-slice) while leaving ~30% headroom.
    if engine.backend_name() == "interp" {
        let interp_bound = (decode_steps + 2) * (3 << 20);
        assert!(
            bytes < interp_bound,
            "interpreter decode allocated {bytes} bytes (> per-token \
             budget {interp_bound}); did buffer reuse (last-use take + \
             in-place dynamic-update-slice) regress?"
        );
    }
}

#[test]
fn zero_copy_data_plane_allocation_budget() {
    reduce_hot_path_does_not_allocate();
    combine_throughput_report();
    stepwise_decode_allocations_bounded_by_step_outputs();
}
