//! Chaos integration: injected rank crashes (`GCORE_CHAOS=kill:rank=R,step=S`)
//! against the elastic `train-dist` supervisor.  The acceptance bar for the
//! fault-tolerance layer: a killed-and-restarted job must produce a final
//! checkpoint **bit-identical** to an uninterrupted run of the same config —
//! on the rendezvous (tcp) AND ring collectives — and a job without a
//! recover policy must fail fast with the worker's typed exit reason, not
//! stall toward the 300 s round timeout.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;
use std::time::Instant;

use gcore::config::{CollectiveMode, RecoverPolicy, RunConfig};
use gcore::runtime::Engine;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join("gcore_chaos_tests")
        .join(format!("{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Loads the tiny artifact set.  PANICS when the set is missing: the
/// fixture set is checked in (rust/tests/fixtures/artifacts/tiny) and the
/// interpreter backend is always available, so there is no legitimate
/// skip reason left — the tier fails loudly if either regresses.
fn try_engine() -> Arc<Engine> {
    match Engine::try_load("tiny") {
        Some(e) => Arc::new(e),
        None => panic!(
            "tiny artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        ),
    }
}

/// A small but checkpoint-carrying distributed run: 2 ranks, 4 RLHF steps,
/// a shard snapshot every 2 steps, fast heartbeats.  The chaos kill at
/// step 3 lands BETWEEN the step-2 and step-4 checkpoints, so restart
/// recovery must replay steps 2..4 from the step-2 shards.
fn base_cfg(collective: &str, ckpt: &Path) -> RunConfig {
    RunConfig {
        artifacts: "tiny".into(),
        world: 2,
        steps: 4,
        sft_steps: 2,
        group_size: 4,
        seed: 23,
        collective: CollectiveMode::parse(collective).unwrap(),
        ring_chunk_bytes: 64, // force multi-chunk gradient streams on ring
        checkpoint_dir: Some(ckpt.to_string_lossy().into_owned()),
        checkpoint_every: 2,
        heartbeat_interval_ms: 25,
        lease_ttl_ms: 500,
        max_restarts: 2,
        ..RunConfig::default()
    }
}

/// Run `gcore train-dist --config <cfg>` as a real OS process tree,
/// optionally with a one-shot chaos kill injected through the environment.
fn run_dist(cfg: &RunConfig, dir: &Path, chaos: Option<&str>) -> std::process::Output {
    let cfg_path = dir.join("run.json");
    std::fs::write(&cfg_path, cfg.to_json().to_string()).unwrap();
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_gcore"));
    cmd.arg("train-dist").arg("--config").arg(&cfg_path);
    // never inherit a kill spec from the surrounding environment
    cmd.env_remove("GCORE_CHAOS");
    if let Some(spec) = chaos {
        cmd.env("GCORE_CHAOS", spec);
    }
    cmd.output().unwrap()
}

fn shard_bytes(ckpt: &Path, step: u64, rank: usize) -> Vec<u8> {
    let p = ckpt.join(format!("step_{step:010}")).join(format!("shard_{rank}.bin"));
    std::fs::read(&p).unwrap_or_else(|e| panic!("missing checkpoint shard {p:?}: {e}"))
}

fn expect_success(out: &std::process::Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed ({})\nstdout:\n{}\nstderr:\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Kill rank 1 before RLHF step 3, restart-recover, and demand the final
/// checkpoint match an uninterrupted run byte for byte — params, Adam
/// moments, reference policy, and both RNG stream positions.
fn chaos_restart_bit_identical(collective: &str) {
    let _e = try_engine();
    let base = tmpdir(&format!("restart_{collective}"));
    let ckpt_ref = base.join("ref_ckpt");
    let ckpt_chaos = base.join("chaos_ckpt");

    let cfg_ref = base_cfg(collective, &ckpt_ref);
    expect_success(&run_dist(&cfg_ref, &base, None), "uninterrupted train-dist");

    let mut cfg_chaos = base_cfg(collective, &ckpt_chaos);
    cfg_chaos.recover = RecoverPolicy::Restart;
    let out = run_dist(&cfg_chaos, &base, Some("kill:rank=1,step=3"));
    expect_success(&out, "chaos train-dist with --recover restart");

    // the kill really fired and recovery really resumed from step 2
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("recovering via restart"),
        "no recovery happened — chaos kill did not fire?\n{stdout}"
    );
    assert!(
        stdout.contains("checkpoint step 2"),
        "recovery did not resume from the step-2 checkpoint\n{stdout}"
    );

    // bit-identical final state on every rank
    for rank in 0..cfg_ref.world {
        assert_eq!(
            shard_bytes(&ckpt_ref, 4, rank),
            shard_bytes(&ckpt_chaos, 4, rank),
            "{collective}: rank {rank} final shard diverged after crash-restart"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn chaos_kill_restart_recovers_bit_identical_tcp() {
    chaos_restart_bit_identical("tcp");
}

#[test]
fn chaos_kill_restart_recovers_bit_identical_ring() {
    chaos_restart_bit_identical("ring");
}

#[test]
fn chaos_without_recover_fails_fast_with_worker_reason() {
    // no recover policy: the job must die promptly with the failed worker
    // named — far under the 300 s collective round timeout the survivors
    // would otherwise sit in.
    let _e = try_engine();
    let base = tmpdir("norecover");
    let mut cfg = base_cfg("tcp", &base.join("ckpt"));
    cfg.checkpoint_dir = None;
    cfg.checkpoint_every = 0;

    let t0 = Instant::now();
    let out = run_dist(&cfg, &base, Some("kill:rank=1,step=1"));
    let elapsed = t0.elapsed();
    assert!(!out.status.success(), "a killed rank must fail the job");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("worker 1 failed"),
        "supervisor must name the dead rank\nstderr:\n{stderr}"
    );
    assert!(
        elapsed.as_secs() < 120,
        "fail-fast took {elapsed:?} — survivors stalled instead of aborting"
    );
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn chaos_shrink_renegotiates_world_down() {
    // --recover shrink: after the kill the job re-rendezvouses at world 1
    // (the largest proper divisor of 2) from the last complete checkpoint
    // and runs to completion.
    let _e = try_engine();
    let base = tmpdir("shrink");
    let ckpt = base.join("ckpt");
    let mut cfg = base_cfg("tcp", &ckpt);
    cfg.recover = RecoverPolicy::Shrink;
    let out = run_dist(&cfg, &base, Some("kill:rank=1,step=3"));
    expect_success(&out, "chaos train-dist with --recover shrink");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("shrinking world 2 -> 1"),
        "shrink policy did not renegotiate the world\n{stdout}"
    );
    // the surviving world finished training and landed its final shard
    let _ = shard_bytes(&ckpt, 4, 0);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn thread_mode_resume_replays_bit_identical() {
    // the same resume path without process spawning: train 4 steps with
    // checkpoints, then resume a FRESH launch from the step-2 shards and
    // demand the replayed half reproduce the original trajectory exactly.
    let _e = try_engine();
    let base = tmpdir("thread_resume");
    let ckpt_a = base.join("a");
    let ckpt_b = base.join("b");

    let cfg_a = RunConfig {
        artifacts: "tiny".into(),
        world: 2,
        steps: 4,
        sft_steps: 2,
        group_size: 4,
        seed: 23,
        checkpoint_dir: Some(ckpt_a.to_string_lossy().into_owned()),
        checkpoint_every: 2,
        ..RunConfig::default()
    };
    let full = gcore::launch::run_training(&cfg_a).unwrap();

    // hand the resumed run ONLY the step-2 checkpoint
    let step2 = "step_0000000002";
    std::fs::create_dir_all(ckpt_b.join(step2)).unwrap();
    for f in ["meta.json", "shard_0.bin", "shard_1.bin"] {
        std::fs::copy(ckpt_a.join(step2).join(f), ckpt_b.join(step2).join(f)).unwrap();
    }
    let cfg_b = RunConfig {
        checkpoint_dir: Some(ckpt_b.to_string_lossy().into_owned()),
        resume_step: Some(2),
        ..cfg_a.clone()
    };
    let resumed = gcore::launch::run_training(&cfg_b).unwrap();

    // the replayed steps 2..4 must match the uninterrupted trajectory ULP
    // for ULP, and so must the final evaluation
    assert_eq!(resumed.steps.len(), 2, "resume must replay exactly steps 2..4");
    for s in &resumed.steps {
        let orig = full
            .steps
            .iter()
            .find(|o| o.step == s.step)
            .unwrap_or_else(|| panic!("step {} missing from the full run", s.step));
        assert_eq!(
            orig.loss.to_bits(),
            s.loss.to_bits(),
            "step {} loss diverged on resume: {} vs {}",
            s.step,
            orig.loss,
            s.loss
        );
        assert_eq!(orig.kl.to_bits(), s.kl.to_bits(), "step {} kl", s.step);
        assert_eq!(
            orig.mean_reward.to_bits(),
            s.mean_reward.to_bits(),
            "step {} reward",
            s.step
        );
    }
    assert_eq!(
        full.eval_after.to_bits(),
        resumed.eval_after.to_bits(),
        "final evaluation diverged on resume"
    );
    // and the step-4 checkpoints are byte-identical shard for shard
    for rank in 0..2 {
        assert_eq!(
            shard_bytes(&ckpt_a, 4, rank),
            shard_bytes(&ckpt_b, 4, rank),
            "rank {rank} final shard diverged on thread-mode resume"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}
