//! Integration tier for the persistent bench database: the store
//! round-tripping real experiment tables through a real file, gate
//! semantics as properties (injected regressions, window edges,
//! bootstrap), Metric render/parse over live tables, and the `gcore
//! bench` CLI surface — run-ingests, report rendering, gate exit codes
//! and the deprecated legacy alias.

use std::path::{Path, PathBuf};
use std::process::Command;

use gcore::bench::{gate, ingest_table, BenchDb, Direction, Metric, Sample, Verdict};
use gcore::experiments;

/// Temp DB file that cleans up after itself even on assertion failure.
struct TempDb(PathBuf);

impl TempDb {
    fn new(name: &str) -> TempDb {
        let p = std::env::temp_dir()
            .join(format!("gcore_bench_it_{}_{name}.jsonl", std::process::id()));
        std::fs::remove_file(&p).ok();
        TempDb(p)
    }

    fn path(&self) -> &Path {
        &self.0
    }

    fn path_str(&self) -> &str {
        self.0.to_str().expect("temp path is utf-8")
    }
}

impl Drop for TempDb {
    fn drop(&mut self) {
        std::fs::remove_file(&self.0).ok();
    }
}

fn lower(label: &str, commit: &str, ts: u64, v: f64) -> Sample {
    Sample::scalar(label, "ms", commit, ts, v, "ms", Direction::LowerIsBetter)
}

#[test]
fn store_roundtrips_experiment_tables_through_a_real_file() {
    let t = TempDb::new("roundtrip");
    let inserted = {
        let mut db = BenchDb::open(t.path()).unwrap();
        let mut n = 0;
        for id in ["e4", "e7"] {
            let table = experiments::run(id, true).unwrap();
            n += ingest_table(&mut db, id, &table, experiments::key_columns(id), "c1", 1)
                .unwrap();
        }
        n
    };
    assert!(inserted > 0, "typed tables must produce gateable samples");

    // a second open() reads everything back from disk
    let db = BenchDb::open(t.path()).unwrap();
    assert_eq!(db.len(), inserted);
    for (label, metric) in db.series_keys() {
        let series = db.series(&label, &metric);
        assert!(!series.is_empty(), "{label} [{metric}]");
        assert!(series.iter().all(|s| s.commit == "c1"));
    }

    // a fresh series has no history: the gate bootstrap-passes
    let r = gate(&db, "c1", 10.0, 5);
    assert!(r.passed());
    assert!(r
        .series
        .iter()
        .all(|s| matches!(s.verdict, Verdict::Bootstrap | Verdict::Skipped)));
}

#[test]
fn injected_regression_fails_iff_above_threshold() {
    for threshold in [5.0_f64, 10.0, 25.0] {
        for inject in [0.0, threshold - 1.0, threshold + 1.0, threshold * 3.0] {
            let t = TempDb::new(&format!("inj_{}_{}", threshold as i64, inject as i64));
            let mut db = BenchDb::open(t.path()).unwrap();
            for (i, c) in ["c1", "c2", "c3"].iter().enumerate() {
                db.insert(lower("e/x", c, i as u64 + 1, 100.0)).unwrap();
            }
            db.insert(lower("e/x", "c9", 9, 100.0 * (1.0 + inject / 100.0))).unwrap();
            let r = gate(&db, "c9", threshold, 5);
            assert_eq!(
                !r.passed(),
                inject > threshold,
                "inject +{inject}% at threshold {threshold}%"
            );
        }
    }
}

#[test]
fn gate_window_edges() {
    let t = TempDb::new("window");
    let mut db = BenchDb::open(t.path()).unwrap();
    // ancient history was 100× faster; the last commit before HEAD is flat
    db.insert(lower("e/x", "c1", 1, 1.0)).unwrap();
    db.insert(lower("e/x", "c2", 2, 100.0)).unwrap();
    db.insert(lower("e/x", "c3", 3, 101.0)).unwrap();
    // window=1 sees only c2: +1% passes
    assert!(gate(&db, "c3", 10.0, 1).passed());
    // window=2 pulls in c1: baseline median{1, 100} = 50.5 → fail
    assert!(!gate(&db, "c3", 10.0, 2).passed());
    // window far larger than history degrades to "all prior commits"
    assert!(!gate(&db, "c3", 10.0, 999).passed());
    // window=0 is clamped to 1, not a panic or a vacuous pass
    assert!(gate(&db, "c3", 10.0, 0).passed());
}

#[test]
fn metric_cells_roundtrip_and_ingest_under_experiment_labels() {
    for id in ["e2", "e3", "e4", "e5", "e7", "e9"] {
        let table = experiments::run(id, true).unwrap();
        for row in table.rendered_rows() {
            for cell in row {
                assert_eq!(
                    Metric::parse(&cell).render(),
                    cell,
                    "{id}: parse/render broke on {cell:?}"
                );
            }
        }
        let t = TempDb::new(&format!("lossless_{id}"));
        let mut db = BenchDb::open(t.path()).unwrap();
        ingest_table(&mut db, id, &table, experiments::key_columns(id), "c1", 1).unwrap();
        for s in db.samples() {
            assert!(s.label.starts_with(&format!("{id}/")), "bad label {:?}", s.label);
        }
    }
}

fn gcore() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gcore"))
}

#[test]
fn cli_gate_exits_zero_on_unchanged_and_nonzero_on_regression() {
    let t = TempDb::new("cli_gate");
    {
        let mut db = BenchDb::open(t.path()).unwrap();
        for (c, ts) in [("c1", 1u64), ("c2", 2), ("c3", 3)] {
            db.insert(lower("e/x", c, ts, 10.0)).unwrap();
        }
        db.insert(lower("e/x", "c4", 4, 10.1)).unwrap();
    }
    let ok = gcore()
        .args(["bench", "gate", "--db", t.path_str(), "--commit", "c4",
               "--threshold-pct", "20", "--window", "5"])
        .output()
        .unwrap();
    assert!(
        ok.status.success(),
        "+1% must pass a 20% gate\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );

    {
        let mut db = BenchDb::open(t.path()).unwrap();
        db.insert(lower("e/x", "c5", 5, 20.0)).unwrap();
    }
    let bad = gcore()
        .args(["bench", "gate", "--db", t.path_str(), "--commit", "c5",
               "--threshold-pct", "20", "--window", "5"])
        .output()
        .unwrap();
    assert!(!bad.status.success(), "+100% must fail a 20% gate");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("bench gate"), "stderr: {stderr}");
    assert!(stderr.contains("e/x"), "failing series named on stderr: {stderr}");
}

#[test]
fn cli_gate_bootstraps_on_an_empty_db() {
    let t = TempDb::new("cli_boot");
    let out = gcore()
        .args(["bench", "gate", "--db", t.path_str(), "--commit", "c1"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("bootstrap"));
}

#[test]
fn cli_run_ingests_then_reports_and_gates() {
    let t = TempDb::new("cli_run");
    let run = gcore()
        .args(["bench", "run", "e4", "--db", t.path_str(), "--commit", "abc123def456"])
        .output()
        .unwrap();
    assert!(
        run.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&run.stderr)
    );
    assert!(String::from_utf8_lossy(&run.stdout).contains("ingested"));

    let db = BenchDb::open(t.path()).unwrap();
    assert!(!db.is_empty());
    assert!(db.samples().iter().all(|s| s.commit == "abc123def456"));

    let report = gcore().args(["bench", "report", "--db", t.path_str()]).output().unwrap();
    assert!(report.status.success());
    assert!(String::from_utf8_lossy(&report.stdout).contains("e4/"));

    let dat = gcore()
        .args(["bench", "report", "--db", t.path_str(), "--format", "dat"])
        .output()
        .unwrap();
    assert!(dat.status.success());
    assert!(String::from_utf8_lossy(&dat.stdout).contains("# e4/"));

    let gated = gcore()
        .args(["bench", "gate", "--db", t.path_str(), "--commit", "abc123def456"])
        .output()
        .unwrap();
    assert!(gated.status.success(), "first ingest must bootstrap-pass the gate");
}

#[test]
fn cli_legacy_alias_still_runs_but_warns() {
    let out = gcore()
        .args(["bench", "e4"])
        .current_dir(std::env::temp_dir())
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("deprecated"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let bad = gcore().args(["bench", "nope"]).output().unwrap();
    assert!(!bad.status.success());
    let bad_run = gcore().args(["bench", "run", "nope"]).output().unwrap();
    assert!(!bad_run.status.success());
}
