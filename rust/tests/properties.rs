//! Cross-module property tests (seeded runner — see util::prop): the
//! coordinator/placement/codec invariants DESIGN.md §6 lists.

use gcore::balance::{assign_balanced, plan_epoch};
use gcore::cluster::sim::{Sim, WorkKind};
use gcore::cluster::workload::GenLenModel;
use gcore::coordinator::sampling::{broadcast_advantages, dapo_filter, gae, grpo_advantages};
use gcore::placement::{run_coexist_static, run_colocate, run_dynamic, PlacementSpec};
use gcore::prop_assert;
use gcore::util::json::Json;
use gcore::util::prop;
use gcore::util::rng::Rng;

#[test]
fn sim_time_conservation() {
    // busy + bubble ≡ makespan × devices, for arbitrary schedules
    prop::check("sim-conservation", |rng| {
        let n = 1 + rng.below(8);
        let mut sim = Sim::new(n);
        for _ in 0..rng.below(40) {
            let d = gcore::cluster::device::DeviceId(rng.below(n));
            let kind = [WorkKind::Generate, WorkKind::Train, WorkKind::Swap][rng.below(3)];
            match rng.below(3) {
                0 => {
                    sim.run_one(d, kind, rng.range(0.0, 10.0));
                }
                1 => {
                    let g: Vec<_> = (0..n).map(gcore::cluster::device::DeviceId).collect();
                    sim.run_group(&g, kind, rng.range(0.0, 10.0));
                }
                _ => {
                    sim.run_one_after(d, rng.range(0.0, 20.0), kind, rng.range(0.0, 10.0));
                }
            }
        }
        let busy: f64 = sim.busy_by_kind().values().sum();
        let total = sim.makespan() * n as f64;
        prop_assert!(
            (busy + sim.bubble_seconds() - total).abs() < 1e-6,
            "conservation violated: busy {busy} bubble {} total {total}",
            sim.bubble_seconds()
        );
        prop_assert!(sim.utilization() <= 1.0 + 1e-9, "util > 1");
        Ok(())
    });
}

#[test]
fn grpo_advantages_invariants() {
    prop::check("grpo-invariants", |rng| {
        let g = 2 + rng.below(6);
        let groups = 1 + rng.below(5);
        let rewards: Vec<f32> = (0..g * groups).map(|_| rng.range(-3.0, 3.0) as f32).collect();
        let adv = grpo_advantages(&rewards, g).unwrap();
        prop_assert!(adv.len() == rewards.len(), "length preserved");
        // reward ordering preserved within each group
        for (gi, chunk) in rewards.chunks(g).enumerate() {
            let achunk = &adv[gi * g..(gi + 1) * g];
            for i in 0..g {
                for j in 0..g {
                    if chunk[i] > chunk[j] {
                        prop_assert!(
                            achunk[i] >= achunk[j],
                            "ordering broken in group {gi}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dapo_filter_keeps_exactly_informative_groups() {
    prop::check("dapo-informative", |rng| {
        let g = 2 + rng.below(4);
        let groups = 1 + rng.below(6);
        // binary rewards
        let rewards: Vec<f32> = (0..g * groups).map(|_| rng.below(2) as f32).collect();
        let keep = dapo_filter(&rewards, g).unwrap();
        for (gi, chunk) in rewards.chunks(g).enumerate() {
            let sum: f32 = chunk.iter().sum();
            let informative = sum > 0.0 && sum < g as f32;
            prop_assert!(
                keep.contains(&gi) == informative,
                "group {gi} (sum {sum}) filter mismatch"
            );
        }
        Ok(())
    });
}

#[test]
fn gae_zero_rewards_perfect_critic_zero_adv() {
    prop::check("gae-zero", |rng| {
        let s = 2 + rng.below(12);
        let rewards = vec![vec![0.0f32; s]];
        let values = vec![vec![0.0f32; s]];
        let masks = vec![vec![1.0f32; s]];
        let (adv, ret) = gae(&rewards, &values, &masks, rng.range(0.5, 1.0) as f32, rng.range(0.5, 1.0) as f32);
        prop_assert!(adv[0].iter().all(|a| a.abs() < 1e-6), "{adv:?}");
        prop_assert!(ret[0].iter().all(|r| r.abs() < 1e-6), "{ret:?}");
        Ok(())
    });
}

#[test]
fn broadcast_advantage_zero_outside_mask() {
    prop::check("broadcast-mask", |rng| {
        let b = 1 + rng.below(4);
        let s = 4 + rng.below(12);
        let adv: Vec<f32> = (0..b).map(|_| rng.range(-2.0, 2.0) as f32).collect();
        let masks: Vec<Vec<f32>> = (0..b)
            .map(|_| (0..s).map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 }).collect())
            .collect();
        let rows = broadcast_advantages(&adv, &masks);
        for (bi, row) in rows.iter().enumerate() {
            for (t, &x) in row.iter().enumerate() {
                if masks[bi][t] == 0.0 {
                    prop_assert!(x == 0.0, "leak at [{bi},{t}]");
                } else {
                    prop_assert!((x - adv[bi]).abs() < 1e-6, "wrong value");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn balanced_assignment_never_worse_than_worst_rank_bound() {
    prop::check("lpt-bound", |rng| {
        let ranks = [2usize, 4, 8][rng.below(3)];
        let per = 4 + rng.below(28);
        let n = ranks * per;
        let glm = GenLenModel::reasoning_default();
        let lens = glm.sample_batch(rng, 0, n);
        let costs: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
        let batch: Vec<usize> = (0..n).collect();
        let a = assign_balanced(&batch, &costs, ranks);
        let rc = a.rank_costs(&costs);
        let max = rc.iter().cloned().fold(0.0, f64::max);
        let mean = rc.iter().sum::<f64>() / ranks as f64;
        // LPT guarantee: makespan ≤ (4/3) · OPT ≤ (4/3) · (mean + max_item)
        let max_item = costs.iter().cloned().fold(0.0, f64::max);
        prop_assert!(
            max <= (mean + max_item) * 4.0 / 3.0 + 1e-9,
            "LPT bound violated: max {max} mean {mean} item {max_item}"
        );
        Ok(())
    });
}

#[test]
fn epoch_buckets_partition_for_all_sizes() {
    prop::check("epoch-partition", |rng| {
        let gb = 8 * (1 + rng.below(8));
        let n = gb * (1 + rng.below(10)) + rng.below(gb); // possibly ragged
        let buckets = plan_epoch(n, gb, rng);
        let mut all: Vec<usize> = buckets.iter().flatten().copied().collect();
        prop_assert!(
            buckets.iter().all(|b| b.len() == gb),
            "all buckets full-sized"
        );
        all.sort_unstable();
        all.dedup();
        prop_assert!(all.len() == buckets.len() * gb, "no duplicates");
        prop_assert!(all.iter().all(|&i| i < n), "indices in range");
        Ok(())
    });
}

#[test]
fn placement_reports_internally_consistent() {
    prop::check("placement-consistency", |rng| {
        let mut spec = PlacementSpec::paper_like();
        spec.steps = 2 + rng.below(4);
        spec.n_devices = 4 * (1 + rng.below(4));
        spec.batch = 32 * (1 + rng.below(4));
        spec.dynamic_sampling = rng.bool(0.5);
        spec.seed = rng.next_u64();
        for r in [
            run_colocate(&spec),
            run_coexist_static(&spec, rng.range(0.2, 0.8)),
            run_dynamic(&spec).report,
        ] {
            prop_assert!(r.makespan_s > 0.0, "zero makespan");
            prop_assert!((0.0..=1.0 + 1e-9).contains(&r.utilization), "util {}", r.utilization);
            prop_assert!(r.bubble_s >= -1e-6, "negative bubble");
            prop_assert!(r.swap_s >= 0.0, "negative swap");
            prop_assert!(r.samples == spec.batch * spec.steps, "sample count");
        }
        Ok(())
    });
}

#[test]
fn json_fuzz_no_panics_and_value_roundtrip() {
    prop::check("json-fuzz", |rng| {
        // random garbage must error, not panic
        let len = rng.below(64);
        let garbage: String = (0..len)
            .map(|_| {
                let chars = b"{}[]\",:0123456789truefalsnl \\x";
                chars[rng.below(chars.len())] as char
            })
            .collect();
        let _ = Json::parse(&garbage); // Ok or Err, never panic

        // random structured values roundtrip exactly
        fn gen_value(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.range(-1e6, 1e6) as i64) as f64),
                3 => Json::Str(format!("s{}\n\"\\{}", rng.below(100), rng.below(100))),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..rng.below(4) {
                        m.insert(format!("k{i}"), gen_value(rng, depth + 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen_value(rng, 0);
        let parsed = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_assert!(parsed == v, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn rpc_wire_frames_roundtrip_exactly() {
    use gcore::rpc::wire::{GatherFrame, GatherReply, PollFrame, Request, Response, Status};
    fn rand_bytes(rng: &mut Rng, max: usize) -> Vec<u8> {
        (0..rng.below(max)).map(|_| rng.below(256) as u8).collect()
    }
    prop::check("wire-roundtrip", |rng| {
        let req = Request {
            id: rng.next_u64(),
            method: format!("m{}.{}", rng.below(100), rng.below(100)),
            payload: rand_bytes(rng, 64),
        };
        prop_assert!(
            Request::decode(&req.encode()).map_err(|e| e.to_string())? == req,
            "request roundtrip"
        );
        let resp = Response {
            id: rng.next_u64(),
            status: [Status::Ok, Status::Err, Status::Cleaned][rng.below(3)],
            payload: rand_bytes(rng, 64),
        };
        prop_assert!(
            Response::decode(&resp.encode()).map_err(|e| e.to_string())? == resp,
            "response roundtrip"
        );
        let frame = GatherFrame {
            seq: rng.next_u64(),
            rank: rng.below(64) as u32,
            world: rng.below(64) as u32,
            tag: ["params", "scalars", "tokens", "barrier"][rng.below(4)].into(),
            payload: rand_bytes(rng, 128),
        };
        let enc = frame.encode();
        prop_assert!(
            GatherFrame::decode(&enc).map_err(|e| e.to_string())? == frame,
            "gather frame roundtrip"
        );
        // truncation must error, never panic
        prop_assert!(
            GatherFrame::decode(&enc[..enc.len() - 1 - rng.below(enc.len() - 1)]).is_err(),
            "truncated gather frame must be rejected"
        );
        let poll = PollFrame { seq: rng.next_u64(), rank: rng.below(64) as u32 };
        prop_assert!(
            PollFrame::decode(&poll.encode()).map_err(|e| e.to_string())? == poll,
            "poll frame roundtrip"
        );
        let reply = if rng.bool(0.3) {
            GatherReply::Pending
        } else {
            GatherReply::Ready((0..rng.below(5)).map(|_| rand_bytes(rng, 48)).collect())
        };
        prop_assert!(
            GatherReply::decode(&reply.encode()).map_err(|e| e.to_string())? == reply,
            "gather reply roundtrip"
        );
        Ok(())
    });
}

#[test]
fn codec_vectors_roundtrip_bit_exact() {
    use gcore::runtime::{Tensor, TensorData};
    use gcore::util::codec::{Reader, Writer};
    prop::check("codec-vec-roundtrip", |rng| {
        // f64 bit patterns, including NaNs/infs/subnormals from raw bits
        let f64s: Vec<f64> = (0..rng.below(24)).map(|_| f64::from_bits(rng.next_u64())).collect();
        let i32s: Vec<i32> = (0..rng.below(24)).map(|_| rng.next_u64() as i32).collect();
        let rows: Vec<Vec<i32>> = (0..rng.below(5))
            .map(|_| (0..rng.below(12)).map(|_| rng.next_u64() as i32).collect())
            .collect();
        let tensors: Vec<Tensor> = (0..rng.below(4))
            .map(|_| {
                let n = rng.below(16);
                match rng.below(3) {
                    0 => Tensor::f32(
                        vec![n],
                        (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect(),
                    ),
                    1 => Tensor::i32(vec![n], (0..n).map(|_| rng.next_u64() as i32).collect()),
                    _ => Tensor::u32(vec![n], (0..n).map(|_| rng.next_u64() as u32).collect()),
                }
            })
            .collect();

        let mut w = Writer::new();
        w.f64s(&f64s);
        w.i32s(&i32s);
        w.token_rows(&rows);
        w.tensors(&tensors);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);

        let f_back = r.f64s().map_err(|e| e.to_string())?;
        prop_assert!(f_back.len() == f64s.len(), "f64 length");
        for (a, b) in f_back.iter().zip(&f64s) {
            prop_assert!(a.to_bits() == b.to_bits(), "f64 bits {a} vs {b}");
        }
        prop_assert!(r.i32s().map_err(|e| e.to_string())? == i32s, "i32s");
        prop_assert!(r.token_rows().map_err(|e| e.to_string())? == rows, "token rows");
        let t_back = r.tensors().map_err(|e| e.to_string())?;
        prop_assert!(t_back.len() == tensors.len(), "tensor count");
        for (a, b) in t_back.iter().zip(&tensors) {
            prop_assert!(a.shape == b.shape, "shape");
            let same = match (&a.data, &b.data) {
                (TensorData::F32(x), TensorData::F32(y)) => {
                    x.iter().map(|v| v.to_bits()).eq(y.iter().map(|v| v.to_bits()))
                }
                (TensorData::I32(x), TensorData::I32(y)) => x == y,
                (TensorData::U32(x), TensorData::U32(y)) => x == y,
                _ => false,
            };
            prop_assert!(same, "tensor payload must roundtrip bit-exactly");
        }
        prop_assert!(r.expect_end().is_ok(), "no trailing bytes");
        Ok(())
    });
}

#[test]
fn codec_fuzz_reader_never_panics() {
    use gcore::util::codec::Reader;
    prop::check("codec-fuzz", |rng| {
        let bytes: Vec<u8> = (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
        let mut r = Reader::new(&bytes);
        // any decode sequence must return Err or Ok, never panic
        let _ = r.u32();
        let _ = r.str();
        let _ = r.tensor();
        let _ = r.tensors();
        Ok(())
    });
}
