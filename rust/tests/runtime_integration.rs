//! Integration: artifacts → engine → numerics.  Exercises the full AOT
//! bridge (HLO text → backend → execution) that every higher layer depends
//! on.  Runs on every build: default-feature builds execute the checked-in
//! fixture artifact set (rust/tests/fixtures/artifacts/tiny, emitted and
//! jax-validated by `python -m compile.fixturegen`) through the pure-Rust
//! HLO interpreter; `pjrt` builds execute the same artifacts through XLA.

use gcore::runtime::{init_policy, init_scalar, Engine, ParamSet, Tensor, TrainState};

/// Loads the tiny artifact set.  Since the interpreter backend landed this
/// PANICS when the set is missing (the fixture set is checked in, so a
/// missing set is a repo defect, not a skip reason) — the tier fails
/// loudly if the interpreter or the fixtures regress.
fn engine() -> Engine {
    Engine::try_load("tiny").unwrap_or_else(|| {
        panic!(
            "tiny artifact set not found — the fixture set should be \
             checked in under rust/tests/fixtures/artifacts/tiny \
             (regenerate with `python -m compile.fixturegen`)"
        )
    })
}

fn dims(e: &Engine) -> (usize, usize, usize, usize) {
    let d = &e.manifest().dims;
    (d.batch, d.max_seq, d.prompt_len, d.vocab)
}

fn fixed_tokens(b: usize, s: usize) -> Tensor {
    // deterministic pseudo-random byte tokens
    let data: Vec<i32> = (0..b * s)
        .map(|i| ((i * 2654435761usize) % 256) as i32)
        .collect();
    Tensor::i32(vec![b, s], data)
}

#[test]
fn init_is_deterministic_and_sized() {
    let e = engine();
    let p1 = init_policy(&e, 42).unwrap();
    let p2 = init_policy(&e, 42).unwrap();
    assert_eq!(p1, p2);
    assert_eq!(p1.num_elements(), e.manifest().param_count);
    let p3 = init_policy(&e, 43).unwrap();
    assert_ne!(p1, p3);
    let s = init_scalar(&e, 0).unwrap();
    assert_eq!(s.num_elements(), e.manifest().scalar_param_count);
}

#[test]
fn fwd_logits_shape_and_finite() {
    let e = engine();
    let (b, s, _, v) = dims(&e);
    let params = init_policy(&e, 0).unwrap();
    let mut inputs = params.tensors.clone();
    inputs.push(fixed_tokens(b, s));
    let out = e.run("fwd_logits", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![b, s, v]);
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn logprob_is_nonpositive_with_zero_first_column() {
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let params = init_policy(&e, 0).unwrap();
    let mut inputs = params.tensors.clone();
    inputs.push(fixed_tokens(b, s));
    let lp = &e.run("logprob", &inputs).unwrap()[0];
    assert_eq!(lp.shape, vec![b, s]);
    let data = lp.as_f32().unwrap();
    for row in 0..b {
        assert_eq!(data[row * s], 0.0, "logp[:,0] must be 0");
    }
    assert!(data.iter().all(|&x| x <= 1e-5));
}

#[test]
fn prefill_decode_matches_full_forward() {
    // The generation-engine contract: KV-cached decode must reproduce the
    // full forward logits position by position.
    let e = engine();
    let (b, s, p, v) = dims(&e);
    let params = init_policy(&e, 7).unwrap();
    let tokens = fixed_tokens(b, s);

    let mut inputs = params.tensors.clone();
    inputs.push(tokens.clone());
    let full = e.run("fwd_logits", &inputs).unwrap().remove(0);
    let full_data = full.as_f32().unwrap();

    // prefill on the first P tokens
    let tok_data = tokens.as_i32().unwrap();
    let prompt: Vec<i32> = (0..b)
        .flat_map(|row| tok_data[row * s..row * s + p].to_vec())
        .collect();
    let mut inputs = params.tensors.clone();
    inputs.push(Tensor::i32(vec![b, p], prompt));
    let mut out = e.run("prefill", &inputs).unwrap();
    let (last, mut ck, mut cv) = (out.remove(0), out.remove(0), out.remove(0));

    // prefill last-logits == full logits at position P-1
    let last_data = last.as_f32().unwrap();
    for row in 0..b {
        for j in 0..v {
            let a = last_data[row * v + j];
            let bq = full_data[row * s * v + (p - 1) * v + j];
            assert!((a - bq).abs() < 2e-4, "prefill row {row} tok {j}: {a} vs {bq}");
        }
    }

    // three decode steps
    for pos in p..p + 3 {
        let step_tok: Vec<i32> = (0..b).map(|row| tok_data[row * s + pos]).collect();
        let mut inputs = params.tensors.clone();
        inputs.push(ck);
        inputs.push(cv);
        inputs.push(Tensor::i32(vec![b], step_tok));
        inputs.push(Tensor::scalar_i32(pos as i32));
        let mut out = e.run("decode_step", &inputs).unwrap();
        let logits = out.remove(0);
        ck = out.remove(0);
        cv = out.remove(0);
        let ld = logits.as_f32().unwrap();
        for row in 0..b {
            for j in 0..v {
                let a = ld[row * v + j];
                let bq = full_data[row * s * v + pos * v + j];
                assert!(
                    (a - bq).abs() < 3e-4,
                    "decode pos {pos} row {row} tok {j}: {a} vs {bq}"
                );
            }
        }
    }
}

#[test]
fn fwd_logits_is_bitwise_deterministic() {
    // Repeated executions of the same artifact on the same inputs must be
    // bit-identical — the property the multi-process SPMD launch relies on
    // (every worker re-derives identical state from the shared seed).
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let params = init_policy(&e, 11).unwrap();
    let mut inputs = params.tensors.clone();
    inputs.push(fixed_tokens(b, s));
    let a = e.run("fwd_logits", &inputs).unwrap().remove(0);
    let c = e.run("fwd_logits", &inputs).unwrap().remove(0);
    let ab: Vec<u32> = a.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
    let cb: Vec<u32> = c.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
    assert_eq!(ab, cb, "forward pass must be bitwise deterministic");
}

#[test]
fn train_step_reduces_loss_and_updates_params() {
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let manifest = e.manifest().clone();
    let params = init_policy(&e, 1).unwrap();
    let tokens = fixed_tokens(b, s);
    let ones = Tensor::f32(vec![b, s], vec![1.0; b * s]);

    // old/ref logprobs from the current policy
    let mut inputs = params.tensors.clone();
    inputs.push(tokens.clone());
    let logp = e.run("logprob", &inputs).unwrap().remove(0);

    let mut state = TrainState::new(params, &manifest.policy_tree);
    let mut losses = Vec::new();
    for step in 1..=4u64 {
        let n = state.params.tensors.len();
        let mut inputs = Vec::with_capacity(3 * n + 10);
        inputs.extend(state.params.tensors.iter().cloned());
        inputs.extend(state.m.tensors.iter().cloned());
        inputs.extend(state.v.tensors.iter().cloned());
        inputs.push(tokens.clone());
        inputs.push(ones.clone()); // mask
        inputs.push(ones.clone()); // advantage +1 everywhere
        inputs.push(logp.clone()); // old_logp
        inputs.push(logp.clone()); // ref_logp
        inputs.push(Tensor::scalar_f32(step as f32));
        inputs.push(Tensor::scalar_f32(1e-3)); // lr
        inputs.push(Tensor::scalar_f32(0.2)); // clip
        inputs.push(Tensor::scalar_f32(0.0)); // kl_coef
        inputs.push(Tensor::scalar_f32(0.0)); // ent_coef
        let mut out = e.run("train_step", &inputs).unwrap();
        let clipfrac = out.pop().unwrap();
        let _entropy = out.pop().unwrap();
        let _kl = out.pop().unwrap();
        let loss = out.pop().unwrap().scalar_value_f32().unwrap();
        losses.push(loss);
        let v = out.split_off(2 * n);
        let m = out.split_off(n);
        state.params = ParamSet::new(out);
        state.m = ParamSet::new(m);
        state.v = ParamSet::new(v);
        assert!(clipfrac.scalar_value_f32().unwrap() >= 0.0);
    }
    // +1 advantage: policy should climb the surrogate => loss decreasing
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "losses {losses:?}"
    );
}

#[test]
fn policy_grad_plus_adam_equals_fused_train_step() {
    // The multi-controller path (grad → reduce → adam) must match the fused
    // single-controller train_step artifact.
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let manifest = e.manifest().clone();
    let params = init_policy(&e, 3).unwrap();
    let tokens = fixed_tokens(b, s);
    let ones = Tensor::f32(vec![b, s], vec![1.0; b * s]);

    let mut inputs = params.tensors.clone();
    inputs.push(tokens.clone());
    let logp = e.run("logprob", &inputs).unwrap().remove(0);

    // path A: fused
    let n = params.tensors.len();
    let zeros = ParamSet::zeros(&manifest.policy_tree);
    let mut inputs = Vec::new();
    inputs.extend(params.tensors.iter().cloned());
    inputs.extend(zeros.tensors.iter().cloned());
    inputs.extend(zeros.tensors.iter().cloned());
    inputs.push(tokens.clone());
    inputs.push(ones.clone());
    inputs.push(ones.clone());
    inputs.push(logp.clone());
    inputs.push(logp.clone());
    inputs.push(Tensor::scalar_f32(1.0));
    inputs.push(Tensor::scalar_f32(1e-3));
    inputs.push(Tensor::scalar_f32(0.2));
    inputs.push(Tensor::scalar_f32(0.01));
    inputs.push(Tensor::scalar_f32(0.0));
    let out_fused = e.run("train_step", &inputs).unwrap();
    let fused_params = &out_fused[..n];

    // path B: policy_grad then adam_policy
    let mut inputs = params.tensors.clone();
    inputs.push(tokens.clone());
    inputs.push(ones.clone());
    inputs.push(ones.clone());
    inputs.push(logp.clone());
    inputs.push(logp.clone());
    inputs.push(Tensor::scalar_f32(0.2));
    inputs.push(Tensor::scalar_f32(0.01));
    inputs.push(Tensor::scalar_f32(0.0));
    let mut gout = e.run("policy_grad", &inputs).unwrap();
    gout.truncate(n); // grads only
    let grads = ParamSet::new(gout);

    let mut state = TrainState::new(params, &manifest.policy_tree);
    state.apply_grads(&e, "adam_policy", &grads, 1e-3).unwrap();

    for (i, (a, b)) in fused_params.iter().zip(&state.params.tensors).enumerate() {
        let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for (x, y) in av.iter().zip(bv) {
            assert!((x - y).abs() < 1e-6, "param tensor {i}: {x} vs {y}");
        }
    }
}

#[test]
fn reward_score_gathers_last_index() {
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let rm = init_scalar(&e, 5).unwrap();
    let tokens = fixed_tokens(b, s);

    let mut inputs = rm.tensors.clone();
    inputs.push(tokens.clone());
    let values = e.run("value_score", &inputs).unwrap().remove(0);
    let vd = values.as_f32().unwrap();

    let idx = s - 2;
    let mut inputs = rm.tensors.clone();
    inputs.push(tokens);
    inputs.push(Tensor::i32(vec![b], vec![idx as i32; b]));
    let scores = e.run("reward_score", &inputs).unwrap().remove(0);
    let sd = scores.as_f32().unwrap();
    for row in 0..b {
        assert!((sd[row] - vd[row * s + idx]).abs() < 1e-6);
    }
}

#[test]
fn bt_grad_learns_preference() {
    let e = engine();
    let (b, s, _, _) = dims(&e);
    let manifest = e.manifest().clone();
    let chosen = fixed_tokens(b, s);
    let rejected = {
        let d: Vec<i32> = chosen.as_i32().unwrap().iter().map(|&x| 255 - x).collect();
        Tensor::i32(vec![b, s], d)
    };
    let idx = Tensor::i32(vec![b], vec![(s - 1) as i32; b]);

    let mut state = TrainState::new(init_scalar(&e, 9).unwrap(), &manifest.scalar_tree);
    let n = state.params.tensors.len();
    let mut first = None;
    let mut last = (0.0, 0.0);
    for _ in 0..12 {
        let mut inputs = state.params.tensors.clone();
        inputs.push(chosen.clone());
        inputs.push(rejected.clone());
        inputs.push(idx.clone());
        inputs.push(idx.clone());
        let mut out = e.run("bt_grad", &inputs).unwrap();
        let acc = out.pop().unwrap().scalar_value_f32().unwrap();
        let loss = out.pop().unwrap().scalar_value_f32().unwrap();
        out.truncate(n);
        let grads = ParamSet::new(out);
        state.apply_grads(&e, "adam_scalar", &grads, 3e-3).unwrap();
        if first.is_none() {
            first = Some(loss);
        }
        last = (loss, acc);
    }
    assert!(last.0 < first.unwrap(), "loss {last:?} vs {first:?}");
    assert_eq!(last.1, 1.0, "pairwise accuracy should reach 1.0");
}

#[test]
fn attn_micro_runs() {
    let e = engine();
    let d = e.manifest().dims.clone();
    let (b, h, s, dh) = (d.batch, d.n_heads, d.max_seq, d.d_head());
    let n = b * h * s * dh;
    let mk = |seed: usize| {
        Tensor::f32(
            vec![b, h, s, dh],
            (0..n).map(|i| (((i + seed) % 17) as f32 - 8.0) / 8.0).collect(),
        )
    };
    let out = e
        .run("attn_micro", &[mk(0), mk(5), mk(11)])
        .unwrap()
        .remove(0);
    assert_eq!(out.shape, vec![b, h, s, dh]);
    assert!(out.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn arity_validation_errors_are_actionable() {
    let e = engine();
    let err = e.run("fwd_logits", &[Tensor::scalar_f32(0.0)]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fwd_logits") && msg.contains("expects"), "{msg}");
}
