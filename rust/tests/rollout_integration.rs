//! Rollout-scheduler integration tier: differential bit-identity against
//! the stepwise reference decoder on the checked-in fixture sets, paged
//! KV-cache admission/exhaustion behavior, prefix-page reuse, long-tail
//! cancellation, and the sampler edge cases (EOS on the first generated
//! token, simultaneous EOS, top_k >= vocab, greedy temperature 0) pinned
//! on hand-written constant-logit artifact sets.

use std::path::PathBuf;

use gcore::coordinator::generation::{self, GenOutput, SamplerConfig};
use gcore::coordinator::rollout::{self, CancelPolicy, RolloutOptions, RolloutRequest};
use gcore::data::tokenizer::{EOS, PAD};
use gcore::runtime::hlo::verify::{self, DiagKind};
use gcore::runtime::{init_policy, Engine, ParamSet, Tensor};
use gcore::util::rng::Rng;

/// Loads a checked-in fixture artifact set.  PANICS when missing: the
/// fixtures are committed and the interpreter backend is always available,
/// so there is no legitimate skip reason (same policy as the coordinator
/// tier).
fn engine(set: &str) -> Engine {
    match Engine::try_load(set) {
        Some(e) => e,
        None => panic!(
            "{set} artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        ),
    }
}

/// Deterministic in-vocab prompts, distinct per row (and per salt).
fn prompts_for(e: &Engine, salt: i32) -> Vec<Vec<i32>> {
    let d = e.manifest().dims.clone();
    (0..d.batch)
        .map(|r| {
            (0..d.prompt_len)
                .map(|c| (r as i32 * 31 + c as i32 * 7 + salt).rem_euclid(d.vocab as i32 - 1) + 1)
                .collect()
        })
        .collect()
}

fn requests(prompts: &[Vec<i32>]) -> Vec<RolloutRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(id, p)| RolloutRequest { id, prompt: p.clone() })
        .collect()
}

fn as_gen_output(run: rollout::RolloutRun) -> GenOutput {
    generation::gen_output_from(run.results)
}

// ---------------------------------------------------------------------------
// differential: scheduler vs stepwise reference on the fixture sets
// ---------------------------------------------------------------------------

#[test]
fn scheduler_matches_stepwise_on_fixture_sets() {
    for set in ["tiny", "synthetic"] {
        let e = engine(set);
        let params = init_policy(&e, 5).unwrap();
        let prompts = prompts_for(&e, 3);
        let cfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };
        let base =
            generation::generate_stepwise(&e, &params, &prompts, &cfg, &mut Rng::new(7)).unwrap();
        for feedback in [false, true] {
            let opts = RolloutOptions { paged_feedback: feedback, ..RolloutOptions::default() };
            let run =
                rollout::run(&e, &params, &requests(&prompts), &cfg, &mut Rng::new(7), &opts)
                    .unwrap();
            let stats = run.stats.clone();
            let out = as_gen_output(run);
            assert_eq!(out.rows, base.rows, "{set} paged_feedback={feedback}");
            assert_eq!(out.gen_lens, base.gen_lens, "{set} paged_feedback={feedback}");
            assert_eq!(out.masks, base.masks, "{set} paged_feedback={feedback}");
            assert_eq!(stats.waves, 1);
            assert_eq!(stats.finished, prompts.len());
            assert_eq!(stats.cancelled, 0);
            assert_eq!(stats.generated_tokens, out.gen_lens.iter().sum::<usize>());
            // dead-row retirement: rows that finish early stop counting as
            // live slot-steps (the waste the scheduler exists to remove)
            if out.gen_lens.iter().any(|&g| g != out.gen_lens[0]) {
                assert!(
                    stats.live_slot_steps < stats.slot_steps,
                    "{set}: early-EOS rows must retire immediately"
                );
            }
        }
        // both fixture sets now ship a fused generate_rollout artifact, so
        // the public entry point refuses a sampler config that disagrees
        // with the baked parameters (top_k=8 here vs baked 16) instead of
        // silently decoding different bits
        let err = generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(7))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{set}: {err}");
    }
}

#[test]
fn fused_rollout_matches_stepwise_and_scheduler_bit_for_bit() {
    // The acceptance bar for the fused generate_rollout artifact: one
    // whole-rollout engine call must reproduce the stepwise
    // prefill/decode_step reference — and the scheduler — bit for bit
    // under a fixed rng seed.  All three paths draw exactly one seed word
    // from the rng per call/wave and share the counter-based sampler.
    for set in ["tiny", "synthetic"] {
        let e = engine(set);
        let baked = e.manifest().sampler.unwrap_or_else(|| {
            panic!("{set}: regenerated fixture sets must carry a baked sampler block")
        });
        assert!(
            e.manifest().artifacts.contains_key("generate_rollout"),
            "{set}: fused generate_rollout artifact missing from the manifest"
        );
        let params = init_policy(&e, 5).unwrap();
        let prompts = prompts_for(&e, 3);
        let cfg = SamplerConfig {
            temperature: 0.8,
            top_k: baked.top_k,
            stop_at_eos: baked.stop_at_eos,
        };
        let base =
            generation::generate_stepwise(&e, &params, &prompts, &cfg, &mut Rng::new(41)).unwrap();
        // sanity: the run generated something beyond a bare EOS somewhere,
        // so the equality below is not vacuous
        assert!(base.gen_lens.iter().any(|&g| g >= 1), "{set}: empty rollout");
        let fused = generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(41)).unwrap();
        assert_eq!(fused.rows, base.rows, "{set} fused vs stepwise rows");
        assert_eq!(fused.gen_lens, base.gen_lens, "{set} fused vs stepwise gen_lens");
        assert_eq!(fused.masks, base.masks, "{set} fused vs stepwise masks");
        let run = rollout::run(
            &e,
            &params,
            &requests(&prompts),
            &cfg,
            &mut Rng::new(41),
            &RolloutOptions::default(),
        )
        .unwrap();
        let sched = as_gen_output(run);
        assert_eq!(sched.rows, base.rows, "{set} scheduler vs stepwise rows");
        assert_eq!(sched.gen_lens, base.gen_lens, "{set} scheduler vs stepwise gen_lens");
        assert_eq!(sched.masks, base.masks, "{set} scheduler vs stepwise masks");
    }
}

#[test]
fn two_waves_match_sequential_stepwise() {
    let e = engine("tiny");
    let params = init_policy(&e, 9).unwrap();
    let first = prompts_for(&e, 1);
    let second = prompts_for(&e, 101);
    let cfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };

    // reference: two stepwise batches drawing from ONE carried rng
    let mut rng = Rng::new(13);
    let base_a = generation::generate_stepwise(&e, &params, &first, &cfg, &mut rng).unwrap();
    let base_b = generation::generate_stepwise(&e, &params, &second, &cfg, &mut rng).unwrap();

    let all: Vec<Vec<i32>> = first.iter().chain(second.iter()).cloned().collect();
    let run = rollout::run(
        &e,
        &params,
        &requests(&all),
        &cfg,
        &mut Rng::new(13),
        &RolloutOptions::default(),
    )
    .unwrap();
    assert_eq!(run.stats.waves, 2);
    assert_eq!(run.stats.prefill_calls, 2);
    let out = as_gen_output(run);
    let b = first.len();
    assert_eq!(&out.rows[..b], &base_a.rows[..]);
    assert_eq!(&out.rows[b..], &base_b.rows[..]);
    assert_eq!(&out.gen_lens[..b], &base_a.gen_lens[..]);
    assert_eq!(&out.gen_lens[b..], &base_b.gen_lens[..]);
}

// ---------------------------------------------------------------------------
// paged pool behavior
// ---------------------------------------------------------------------------

#[test]
fn page_pool_exhaustion_blocks_admission_without_panicking() {
    let e = engine("tiny");
    let dims = e.manifest().dims.clone();
    let params = init_policy(&e, 4).unwrap();
    let prompts = prompts_for(&e, 17);
    let cfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };
    // pool sized for exactly ONE sequence: every other admission must wait
    let pps = dims.max_seq.div_ceil(rollout::DEFAULT_PAGE_SIZE);
    let opts = RolloutOptions {
        pool_pages: pps,
        share_prefixes: false,
        ..RolloutOptions::default()
    };
    let run =
        rollout::run(&e, &params, &requests(&prompts), &cfg, &mut Rng::new(3), &opts).unwrap();
    assert_eq!(run.stats.waves, dims.batch, "one sequence per wave");
    assert!(run.stats.admission_waits >= dims.batch - 1);
    assert!(run.stats.peak_pages <= pps, "pool cap must hold");
    assert_eq!(run.results.len(), dims.batch);
    for (i, r) in run.results.iter().enumerate() {
        assert!(!r.cancelled, "request {i} must complete, not be dropped");
        assert!(r.gen_len >= 1);
        assert_eq!(&r.row[..dims.prompt_len], &prompts[i][..]);
        assert_eq!(r.mask.iter().sum::<f32>() as usize, r.gen_len);
    }
}

#[test]
fn prefix_sharing_reuses_pages_and_keeps_bits() {
    let e = engine("tiny");
    let dims = e.manifest().dims.clone();
    let params = init_policy(&e, 6).unwrap();
    // every request carries the SAME prompt → wave 2 maps wave 1's
    // published prompt pages instead of recomputing/rescattering them
    let prompt = prompts_for(&e, 23)[0].clone();
    let all: Vec<Vec<i32>> = (0..2 * dims.batch).map(|_| prompt.clone()).collect();
    let cfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };

    let shared_opts = RolloutOptions { paged_feedback: true, ..RolloutOptions::default() };
    let shared =
        rollout::run(&e, &params, &requests(&all), &cfg, &mut Rng::new(21), &shared_opts).unwrap();
    assert!(
        shared.stats.shared_page_hits >= 1,
        "identical prompts across waves must hit the share index"
    );

    // sharing must be a pure allocation optimization: same seed, sharing
    // off, dense passthrough — identical bits
    let plain_opts = RolloutOptions { share_prefixes: false, ..RolloutOptions::default() };
    let plain =
        rollout::run(&e, &params, &requests(&all), &cfg, &mut Rng::new(21), &plain_opts).unwrap();
    let a = as_gen_output(shared);
    let b = as_gen_output(plain);
    assert_eq!(a.rows, b.rows);
    assert_eq!(a.gen_lens, b.gen_lens);
    assert_eq!(a.masks, b.masks);
}

// ---------------------------------------------------------------------------
// hand-written constant-logit artifact sets: sampler edge cases with
// deterministic EOS timing (vocab 11 → greedy argmax is 10 == EOS on the
// very first token; vocab 12 → argmax 11, never EOS)
// ---------------------------------------------------------------------------

const MICRO_CACHE: &str = "f32[1,2,1,6,4]";

/// Extra manifest content for the fused-gate tests.
enum Gate {
    None,
    /// `generate_rollout` present, no "sampler" block
    NoSampler,
    /// `generate_rollout` present, sampler baked with top_k=4
    Baked,
}

/// Write a 2-row, prompt_len=2, max_seq=6 artifact set whose prefill and
/// decode_step emit constant logits and zero caches, returning its
/// directory.  `row_target` makes prefill logits one-hot at column 10+row
/// instead (row 0 → EOS, row 1 → a non-EOS token) so EOS timing diverges
/// across rows deterministically.
fn micro_set_dir(name: &str, vocab: usize, row_target: bool, gate: Gate) -> PathBuf {
    let dir: PathBuf = std::env::temp_dir()
        .join("gcore_rollout_tests")
        .join(format!("{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let logits = if row_target {
        assert!(vocab > 11, "row targets are columns 10 and 11");
        format!(
            "  %rows = s32[2,{vocab}] iota(), iota_dimension=0\n  \
             %cols = s32[2,{vocab}] iota(), iota_dimension=1\n  \
             %c10 = s32[] constant(10)\n  \
             %b10 = s32[2,{vocab}] broadcast(s32[] %c10), dimensions={{}}\n  \
             %tgt = s32[2,{vocab}] add(s32[2,{vocab}] %rows, s32[2,{vocab}] %b10)\n  \
             %eq = pred[2,{vocab}] compare(s32[2,{vocab}] %cols, s32[2,{vocab}] %tgt), direction=EQ\n  \
             %c5 = f32[] constant(5)\n  \
             %hi = f32[2,{vocab}] broadcast(f32[] %c5), dimensions={{}}\n  \
             %lo = f32[2,{vocab}] broadcast(f32[] %c0), dimensions={{}}\n  \
             %logits = f32[2,{vocab}] select(pred[2,{vocab}] %eq, f32[2,{vocab}] %hi, f32[2,{vocab}] %lo)\n"
        )
    } else {
        format!("  %logits = f32[2,{vocab}] broadcast(f32[] %c0), dimensions={{}}\n")
    };
    let prefill = format!(
        "HloModule prefill\n\n\
         ENTRY %entry (p0: f32[1], p1: s32[2,2]) -> (f32[2,{vocab}], {MICRO_CACHE}, {MICRO_CACHE}) {{\n  \
         %v0 = f32[1] parameter(0)\n  \
         %v1 = s32[2,2] parameter(1)\n  \
         %c0 = f32[] constant(0)\n\
         {logits}  \
         %ck = {MICRO_CACHE} broadcast(f32[] %c0), dimensions={{}}\n  \
         %cv = {MICRO_CACHE} broadcast(f32[] %c0), dimensions={{}}\n  \
         ROOT %result = (f32[2,{vocab}], {MICRO_CACHE}, {MICRO_CACHE}) tuple(f32[2,{vocab}] %logits, {MICRO_CACHE} %ck, {MICRO_CACHE} %cv)\n\
         }}\n"
    );
    let decode = format!(
        "HloModule decode_step\n\n\
         ENTRY %entry (p0: f32[1], p1: {MICRO_CACHE}, p2: {MICRO_CACHE}, p3: s32[2], p4: s32[]) -> (f32[2,{vocab}], {MICRO_CACHE}, {MICRO_CACHE}) {{\n  \
         %v0 = f32[1] parameter(0)\n  \
         %v1 = {MICRO_CACHE} parameter(1)\n  \
         %v2 = {MICRO_CACHE} parameter(2)\n  \
         %v3 = s32[2] parameter(3)\n  \
         %v4 = s32[] parameter(4)\n  \
         %c0 = f32[] constant(0)\n  \
         %logits = f32[2,{vocab}] broadcast(f32[] %c0), dimensions={{}}\n  \
         ROOT %result = (f32[2,{vocab}], {MICRO_CACHE}, {MICRO_CACHE}) tuple(f32[2,{vocab}] %logits, {MICRO_CACHE} %v1, {MICRO_CACHE} %v2)\n\
         }}\n"
    );
    std::fs::write(dir.join("prefill.hlo.txt"), prefill).unwrap();
    std::fs::write(dir.join("decode_step.hlo.txt"), decode).unwrap();

    let cache_shape = "[1, 2, 1, 6, 4]";
    let outputs = format!(
        r#"[{{"name": "out/0", "shape": [2, {vocab}], "dtype": "f32"}},
            {{"name": "out/1", "shape": {cache_shape}, "dtype": "f32"}},
            {{"name": "out/2", "shape": {cache_shape}, "dtype": "f32"}}]"#
    );
    // the gate bails before ever touching the fused artifact, so its HLO
    // file deliberately does not exist — reaching for it would be a bug
    let fused = match gate {
        Gate::None => "",
        Gate::NoSampler | Gate::Baked => {
            r#", "generate_rollout": {"file": "generate_rollout.hlo.txt",
                "inputs": [{"name": "p/w", "shape": [1], "dtype": "f32"},
                           {"name": "prompts", "shape": [2, 2], "dtype": "i32"},
                           {"name": "seed", "shape": [], "dtype": "u32"},
                           {"name": "temperature", "shape": [], "dtype": "f32"}],
                "outputs": [{"name": "rows", "shape": [2, 6], "dtype": "i32"}],
                "hlo_bytes": 0}"#
        }
    };
    let sampler = match gate {
        Gate::Baked => r#", "sampler": {"top_k": 4, "stop_at_eos": true}"#,
        _ => "",
    };
    let manifest = format!(
        r#"{{
"config": {{"name": "micro", "vocab": {vocab}, "d_model": 4, "n_layers": 1,
           "n_heads": 1, "d_ff": 4, "max_seq": 6, "prompt_len": 2,
           "batch": 2, "use_pallas": false}},
"param_count": 1,
"scalar_param_count": 1,
"policy_tree": [{{"path": "p/w", "shape": [1], "dtype": "f32"}}],
"scalar_tree": [{{"path": "p/w", "shape": [1], "dtype": "f32"}}],
"artifacts": {{
 "prefill": {{"file": "prefill.hlo.txt",
   "inputs": [{{"name": "p/w", "shape": [1], "dtype": "f32"}},
              {{"name": "tokens", "shape": [2, 2], "dtype": "i32"}}],
   "outputs": {outputs}, "hlo_bytes": 1}},
 "decode_step": {{"file": "decode_step.hlo.txt",
   "inputs": [{{"name": "p/w", "shape": [1], "dtype": "f32"}},
              {{"name": "cache_k", "shape": {cache_shape}, "dtype": "f32"}},
              {{"name": "cache_v", "shape": {cache_shape}, "dtype": "f32"}},
              {{"name": "token", "shape": [2], "dtype": "i32"}},
              {{"name": "pos", "shape": [], "dtype": "i32"}}],
   "outputs": {outputs}, "hlo_bytes": 1}}{fused}
}}{sampler}
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

fn micro_engine(name: &str, vocab: usize, row_target: bool, gate: Gate) -> Engine {
    Engine::from_dir(&micro_set_dir(name, vocab, row_target, gate)).unwrap()
}

fn micro_params() -> ParamSet {
    ParamSet::new(vec![Tensor::f32(vec![1], vec![0.0])])
}

fn micro_prompts() -> Vec<Vec<i32>> {
    vec![vec![1, 2], vec![3, 4]]
}

const GREEDY: SamplerConfig = SamplerConfig { temperature: 0.0, top_k: 1, stop_at_eos: true };

#[test]
fn eos_on_first_token_and_all_rows_simultaneously() {
    // vocab 11, all-zero logits: greedy argmax (last max wins on ties) is
    // index 10 == EOS — every row emits EOS as its first generated token
    let e = micro_engine("eos_first", 11, false, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let base =
        generation::generate_stepwise(&e, &params, &prompts, &GREEDY, &mut Rng::new(1)).unwrap();
    let run = rollout::run(
        &e,
        &params,
        &requests(&prompts),
        &GREEDY,
        &mut Rng::new(2), // greedy: rng must not matter
        &RolloutOptions::default(),
    )
    .unwrap();
    assert_eq!(run.stats.decode_calls, 0, "all rows retire at the first sample");
    assert_eq!(run.stats.generated_tokens, 2);
    let out = as_gen_output(run);
    assert_eq!(out.rows, base.rows);
    assert_eq!(out.gen_lens, base.gen_lens);
    assert_eq!(out.masks, base.masks);
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(out.gen_lens[i], 1);
        assert_eq!(out.rows[i], vec![p[0], p[1], EOS, PAD, PAD, PAD]);
        assert_eq!(out.masks[i], vec![0.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
    }
}

#[test]
fn greedy_without_eos_runs_to_the_length_cap() {
    // vocab 12: argmax is 11, never EOS — rows fill to max_seq
    let e = micro_engine("never_eos", 12, false, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let base =
        generation::generate_stepwise(&e, &params, &prompts, &GREEDY, &mut Rng::new(5)).unwrap();
    let run = rollout::run(
        &e,
        &params,
        &requests(&prompts),
        &GREEDY,
        &mut Rng::new(6),
        &RolloutOptions::default(),
    )
    .unwrap();
    assert_eq!(run.stats.decode_calls, 3); // positions 2..=4 decode, 5 is the cap
    let out = as_gen_output(run);
    assert_eq!(out.rows, base.rows);
    assert_eq!(out.gen_lens, base.gen_lens);
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(out.gen_lens[i], 4);
        assert_eq!(out.rows[i], vec![p[0], p[1], 11, 11, 11, 11]);
        assert_eq!(out.masks[i].iter().sum::<f32>(), 4.0);
    }
}

#[test]
fn top_k_larger_than_vocab_is_clamped_identically() {
    let e = micro_engine("topk_clamp", 12, false, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let cfg = SamplerConfig { temperature: 1.0, top_k: 64, stop_at_eos: true };
    let base =
        generation::generate_stepwise(&e, &params, &prompts, &cfg, &mut Rng::new(31)).unwrap();
    for feedback in [false, true] {
        let opts = RolloutOptions { paged_feedback: feedback, ..RolloutOptions::default() };
        let run = rollout::run(&e, &params, &requests(&prompts), &cfg, &mut Rng::new(31), &opts)
            .unwrap();
        let out = as_gen_output(run);
        assert_eq!(out.rows, base.rows, "paged_feedback={feedback}");
        assert_eq!(out.gen_lens, base.gen_lens);
        assert!(out.rows.iter().flatten().all(|&t| t < 12));
    }
}

#[test]
fn cancellation_preempts_stragglers_and_reclaims_pages() {
    // prefill logits: row 0 → EOS immediately, row 1 → token 11 (never
    // EOS); zero-grace policy with needed=1 preempts row 1 right away
    let e = micro_engine("cancel_zero_grace", 12, true, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let opts = RolloutOptions {
        cancel: Some(CancelPolicy { needed: 1, grace_steps: 0 }),
        ..RolloutOptions::default()
    };
    let run =
        rollout::run(&e, &params, &requests(&prompts), &GREEDY, &mut Rng::new(1), &opts).unwrap();
    assert_eq!(run.stats.finished, 1);
    assert_eq!(run.stats.cancelled, 1);
    let r0 = &run.results[0];
    assert!(!r0.cancelled);
    assert_eq!(r0.gen_len, 1);
    assert_eq!(r0.row, vec![1, 2, EOS, PAD, PAD, PAD]);
    let r1 = &run.results[1];
    assert!(r1.cancelled);
    assert_eq!(r1.gen_len, 1);
    assert_eq!(r1.row, vec![3, 4, 11, PAD, PAD, PAD]);
    assert_eq!(r1.mask.iter().sum::<f32>() as usize, r1.gen_len);
}

#[test]
fn generous_grace_lets_stragglers_finish() {
    let e = micro_engine("cancel_grace", 12, true, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let opts = RolloutOptions {
        cancel: Some(CancelPolicy { needed: 1, grace_steps: 8 }),
        ..RolloutOptions::default()
    };
    let run =
        rollout::run(&e, &params, &requests(&prompts), &GREEDY, &mut Rng::new(1), &opts).unwrap();
    // grace (scaled: ceil(8 * 1/2) = 4) outlasts the 3 remaining decode
    // steps to the cap — nothing is cancelled
    assert_eq!(run.stats.cancelled, 0);
    assert_eq!(run.stats.finished, 2);
    assert_eq!(run.results[1].gen_len, 4);
}

#[test]
fn cancellation_drains_never_admitted_requests() {
    // 3 waves' worth of never-EOS requests: wave 1 finishes at the cap,
    // arming the policy; wave 2 is preempted at its first sample; the
    // remaining queue never runs and comes back cancelled with gen_len 0
    let e = micro_engine("cancel_queue", 12, false, Gate::None);
    let params = micro_params();
    let all: Vec<Vec<i32>> = (0..6).map(|i| vec![1 + (i as i32 % 2), 5]).collect();
    let opts = RolloutOptions {
        cancel: Some(CancelPolicy { needed: 1, grace_steps: 0 }),
        ..RolloutOptions::default()
    };
    let run =
        rollout::run(&e, &params, &requests(&all), &GREEDY, &mut Rng::new(1), &opts).unwrap();
    assert_eq!(run.results.len(), 6);
    assert_eq!(run.stats.finished, 2);
    assert_eq!(run.stats.cancelled, 4);
    for r in &run.results[..2] {
        assert!(!r.cancelled);
        assert_eq!(r.gen_len, 4);
    }
    for r in &run.results[2..4] {
        assert!(r.cancelled);
        assert_eq!(r.gen_len, 1, "wave-2 rows are preempted after one sample");
    }
    for (i, r) in run.results[4..].iter().enumerate() {
        assert!(r.cancelled);
        assert_eq!(r.gen_len, 0, "request {} never ran", i + 4);
        assert_eq!(&r.row[..2], &all[i + 4][..]);
        assert!(r.row[2..].iter().all(|&t| t == PAD));
        assert!(r.mask.iter().all(|&m| m == 0.0));
    }
}

#[test]
fn micro_exhaustion_with_small_pages_blocks_and_completes() {
    let e = micro_engine("micro_pool", 12, false, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    // page_size 2 → 3 pages per sequence; pool of 3 → one sequence at a time
    let opts = RolloutOptions {
        page_size: 2,
        pool_pages: 3,
        share_prefixes: false,
        paged_feedback: true,
        ..RolloutOptions::default()
    };
    let base =
        generation::generate_stepwise(&e, &params, &prompts, &GREEDY, &mut Rng::new(1)).unwrap();
    let run =
        rollout::run(&e, &params, &requests(&prompts), &GREEDY, &mut Rng::new(1), &opts).unwrap();
    assert_eq!(run.stats.waves, 2);
    assert!(run.stats.admission_waits >= 1);
    assert!(run.stats.peak_pages <= 3);
    let out = as_gen_output(run);
    // greedy + constant logits: per-wave decode equals the batch reference
    assert_eq!(out.rows, base.rows);
    assert_eq!(out.gen_lens, base.gen_lens);
}

// ---------------------------------------------------------------------------
// fused-path gate (satellite: the old `top_k == 16` magic-constant check)
// ---------------------------------------------------------------------------

#[test]
fn fused_gate_rejects_mismatched_sampler_config() {
    let e = micro_engine("gate_mismatch", 11, false, Gate::Baked);
    let params = micro_params();
    let prompts = micro_prompts();
    let cfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };
    let msg = format!(
        "{:#}",
        generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(1)).unwrap_err()
    );
    assert!(msg.contains("does not match"), "{msg}");
    assert!(msg.contains("top_k=4"), "{msg}");
}

#[test]
fn fused_gate_rejects_missing_sampler_block() {
    let e = micro_engine("gate_missing", 11, false, Gate::NoSampler);
    let params = micro_params();
    let prompts = micro_prompts();
    let cfg = SamplerConfig { temperature: 1.0, top_k: 16, stop_at_eos: true };
    let msg = format!(
        "{:#}",
        generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(1)).unwrap_err()
    );
    assert!(msg.contains("sampler"), "{msg}");
    assert!(msg.contains("regenerate"), "{msg}");
}

#[test]
fn greedy_request_bypasses_the_fused_gate() {
    // temperature <= 0 is an explicit argmax ask the stochastic fused
    // module cannot express — it must take the per-token path even on a
    // set carrying generate_rollout (whose HLO here does not even exist)
    let e = micro_engine("gate_greedy", 11, false, Gate::Baked);
    let params = micro_params();
    let prompts = micro_prompts();
    let out = generation::generate(&e, &params, &prompts, &GREEDY, &mut Rng::new(1)).unwrap();
    assert_eq!(out.gen_lens, vec![1, 1]);
    assert_eq!(out.rows[0], vec![1, 2, EOS, PAD, PAD, PAD]);
}

// ---------------------------------------------------------------------------
// accounting rule (satellite: dead-row PAD/mask bookkeeping)
// ---------------------------------------------------------------------------

#[test]
fn account_row_pins_the_shared_accounting_rule() {
    // EOS mid-span: gen length runs to the first EOS inclusive, the tail
    // is PAD, the mask covers exactly the span
    let mut row = vec![1, 2, 5, EOS, 7, 9];
    let (glen, mask) = generation::account_row(&mut row, 2, true);
    assert_eq!(glen, 2);
    assert_eq!(row, vec![1, 2, 5, EOS, PAD, PAD]);
    assert_eq!(mask, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0]);

    // no EOS: the whole generated span counts
    let mut row = vec![1, 2, 5, 6, 7, 9];
    let (glen, mask) = generation::account_row(&mut row, 2, true);
    assert_eq!(glen, 4);
    assert_eq!(row, vec![1, 2, 5, 6, 7, 9]);
    assert_eq!(mask.iter().sum::<f32>(), 4.0);

    // stop_at_eos = false: EOS is just a token (alloc-count pins rely on
    // this — the scheduler must keep decoding through it)
    let mut row = vec![1, 2, EOS, 6, 7, 9];
    let (glen, _) = generation::account_row(&mut row, 2, false);
    assert_eq!(glen, 4);
    assert_eq!(row, vec![1, 2, EOS, 6, 7, 9]);
}

#[test]
fn stop_at_eos_false_decodes_through_eos_identically() {
    // vocab 11 zero logits: every sampled token is EOS, but with
    // stop_at_eos=false rows must decode to the cap anyway (the greedy
    // evaluate()/alloc-count path depends on this)
    let e = micro_engine("no_stop", 11, false, Gate::None);
    let params = micro_params();
    let prompts = micro_prompts();
    let cfg = SamplerConfig { temperature: 0.0, top_k: 1, stop_at_eos: false };
    let base =
        generation::generate_stepwise(&e, &params, &prompts, &cfg, &mut Rng::new(1)).unwrap();
    let run = rollout::run(
        &e,
        &params,
        &requests(&prompts),
        &cfg,
        &mut Rng::new(2),
        &RolloutOptions::default(),
    )
    .unwrap();
    let out = as_gen_output(run);
    assert_eq!(out.rows, base.rows);
    assert_eq!(out.gen_lens, vec![4, 4]);
    for row in &out.rows {
        assert_eq!(&row[2..], &[EOS, EOS, EOS, EOS]);
    }
}

// ---------------------------------------------------------------------------
// static lint gate over the generated micro sets (same gate CI runs over the
// checked-in fixture sets via `gcore hlo-lint`)
// ---------------------------------------------------------------------------

#[test]
fn micro_sets_lint_clean() {
    // both HLO shapes the generator emits: constant logits and the
    // iota/compare/select row-target variant
    for (name, vocab, row_target) in
        [("lint_const", 11, false), ("lint_rowtgt", 12, true)]
    {
        let dir = micro_set_dir(name, vocab, row_target, Gate::None);
        let report = verify::lint_set(&dir).unwrap();
        assert_eq!(
            report.total_diagnostics(),
            0,
            "micro set {name} must verify clean: {:?}",
            report
                .artifacts
                .iter()
                .flat_map(|a| &a.diagnostics)
                .collect::<Vec<_>>()
        );
        for a in &report.artifacts {
            let plan = a.plan.as_ref().expect("clean artifact must carry a plan");
            assert_eq!(plan.last_use.len(), a.instrs);
        }
    }
}

#[test]
fn gated_micro_set_lint_reports_only_the_missing_fused_artifact() {
    // the fused `generate_rollout` entry deliberately has no HLO file on
    // disk; the lint must flag exactly that and nothing else
    let dir = micro_set_dir("lint_gated", 11, false, Gate::Baked);
    let report = verify::lint_set(&dir).unwrap();
    assert_eq!(report.total_diagnostics(), 1);
    let bad = report
        .artifacts
        .iter()
        .find(|a| !a.diagnostics.is_empty())
        .unwrap();
    assert_eq!(bad.name, "generate_rollout");
    assert_eq!(bad.diagnostics[0].kind, DiagKind::ParseError);
    assert!(bad.diagnostics[0].message.contains("cannot read"));
    // and the engine still loads: eager verification skips artifacts whose
    // HLO file is absent (the gate bails before touching the fused path)
    Engine::from_dir(&dir).unwrap();
}
