//! Exactly-once collective properties: the RPC-backed collective, driven
//! through the fault-injecting transport (request drops, response drops,
//! duplicate deliveries), must produce results **bit-identical** to the
//! in-proc `Rendezvous` backend — the correctness core of the paper's
//! retry-until-cached protocol (§4.2) applied to collectives (§3.1).

use std::sync::Arc;
use std::time::{Duration, Instant};

use gcore::coordinator::collective::{Collective, CollectiveBackend};
use gcore::coordinator::ring_collective::{RingCollective, RingInbox, RingPeer};
use gcore::coordinator::rpc_collective::{
    CollectiveStatus, Heartbeat, RendezvousHost, RpcCollective,
};
use gcore::prop_assert;
use gcore::rpc::client::{RetryPolicy, RpcClient};
use gcore::rpc::transport::{FlakyTransport, InProcTransport, TcpRpcHost, TcpTransport};
use gcore::runtime::{ParamSet, Tensor};
use gcore::util::prop;
use gcore::util::rng::Rng;

/// Deterministic per-(rank, round) operand, same shapes on every rank.
fn operand(shapes: &[usize], rank: usize, round: usize, seed: u64) -> ParamSet {
    let mut rng = Rng::new(seed ^ ((rank as u64) << 32) ^ (round as u64));
    ParamSet::new(
        shapes
            .iter()
            .map(|&n| Tensor::f32(vec![n], (0..n).map(|_| rng.range(-4.0, 4.0) as f32).collect()))
            .collect(),
    )
}

fn bits(set: &ParamSet) -> Vec<u32> {
    set.tensors
        .iter()
        .flat_map(|t| t.as_f32().unwrap().iter().map(|f| f.to_bits()))
        .collect()
}

/// Run `rounds` all-reduce rounds on every rank of `collectives`; returns
/// per-rank, per-round results (or the first error).
fn drive(
    collectives: Vec<Arc<Collective>>,
    shapes: Vec<usize>,
    rounds: usize,
    seed: u64,
) -> Result<Vec<Vec<ParamSet>>, String> {
    let handles: Vec<_> = collectives
        .into_iter()
        .enumerate()
        .map(|(rank, col)| {
            let shapes = shapes.clone();
            std::thread::spawn(move || -> Result<Vec<ParamSet>, String> {
                (0..rounds)
                    .map(|round| {
                        col.all_reduce_mean(rank, &operand(&shapes, rank, round, seed))
                            .map_err(|e| format!("rank {rank} round {round}: {e:#}"))
                    })
                    .collect()
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().map_err(|_| "rank panicked".to_string())?)
        .collect()
}

#[test]
fn rpc_collective_bitwise_matches_inproc_under_faults() {
    // Heavier per-case than most properties (thread groups + fault-injected
    // transports): cap the cases while still sweeping world size / shapes /
    // fault seeds.
    prop::check_n("rpc-collective-bitwise", 24, |rng| {
        let world = 2 + rng.below(2); // 2..=3 ranks
        let rounds = 1 + rng.below(3);
        let shapes: Vec<usize> = (0..1 + rng.below(2)).map(|_| 1 + rng.below(32)).collect();
        let seed = rng.next_u64();

        // reference: in-proc rendezvous backend
        let inproc = Collective::new(world);
        let reference = drive(
            (0..world).map(|_| inproc.clone()).collect(),
            shapes.clone(),
            rounds,
            seed,
        )?;

        // RPC backend through drops + duplicates
        let server = RendezvousHost::serve(world);
        let collectives: Vec<Arc<Collective>> = (0..world)
            .map(|rank| {
                let flaky = FlakyTransport::new(
                    InProcTransport::new(server.clone()),
                    seed ^ (0xF1A6 + rank as u64),
                )
                .with_probs(0.15, 0.25, 0.15);
                let backend = RpcCollective::new(flaky, world)
                    .with_retry(RetryPolicy::exponential(256, Duration::from_micros(10)))
                    .with_round_timeout(Duration::from_secs(60));
                Collective::with_backend(Arc::new(backend))
            })
            .collect();
        let rpc_results = drive(collectives, shapes, rounds, seed)?;

        for (rank, (a, b)) in reference.iter().zip(&rpc_results).enumerate() {
            for (round, (ra, rb)) in a.iter().zip(b).enumerate() {
                prop_assert!(
                    bits(ra) == bits(rb),
                    "rank {rank} round {round}: RPC result diverged from in-proc"
                );
            }
        }
        let stats = server.stats();
        prop_assert!(
            stats.cached_now == 0,
            "retry-until-cached must drain the result cache ({} left)",
            stats.cached_now
        );
        prop_assert!(
            server.service().open_rounds() == 0,
            "completed rounds must be garbage-collected"
        );
        Ok(())
    });
}

/// Build an in-process ring whose successor transports go through `wrap`
/// (identity or fault injection).  Returns (inboxes, collectives).
fn ring_group<T, F>(
    world: usize,
    chunk_bytes: usize,
    wrap: F,
) -> (Vec<Arc<RingInbox>>, Vec<Arc<Collective>>)
where
    T: gcore::rpc::transport::Transport + 'static,
    F: Fn(usize, Arc<gcore::rpc::server::RpcServer<RingPeer>>) -> T,
{
    let inboxes: Vec<Arc<RingInbox>> = (0..world).map(|_| RingInbox::new()).collect();
    let servers: Vec<_> = inboxes.iter().map(|ib| RingPeer::serve(ib.clone())).collect();
    let collectives = (0..world)
        .map(|rank| {
            let succ = wrap(rank, servers[(rank + 1) % world].clone());
            Collective::with_backend(Arc::new(
                RingCollective::new(rank, world, inboxes[rank].clone(), succ)
                    .with_chunk_bytes(chunk_bytes)
                    .with_window(2)
                    .with_round_timeout(Duration::from_secs(60)),
            ))
        })
        .collect();
    (inboxes, collectives)
}

#[test]
fn ring_collective_bitwise_matches_inproc_under_faults() {
    // The tentpole invariant: the chunked streaming ring — driven through
    // drops, duplicate deliveries and lost responses — must reproduce the
    // in-proc backend's all-reduce bit-for-bit, because both accumulate in
    // strict rank order.
    prop::check_n("ring-collective-bitwise", 24, |rng| {
        let world = 2 + rng.below(3); // 2..=4 ranks
        let rounds = 1 + rng.below(3);
        let shapes: Vec<usize> = (0..1 + rng.below(2)).map(|_| 1 + rng.below(32)).collect();
        // tiny chunks force multi-chunk streaming + the credit window
        let chunk_bytes = 16 + 4 * rng.below(9);
        let seed = rng.next_u64();

        let inproc = Collective::new(world);
        let reference = drive(
            (0..world).map(|_| inproc.clone()).collect(),
            shapes.clone(),
            rounds,
            seed,
        )?;

        let (inboxes, collectives) = ring_group(world, chunk_bytes, |rank, server| {
            FlakyTransport::new(
                InProcTransport::new(server),
                seed ^ (0xB1A6u64.wrapping_add(rank as u64)),
            )
            .with_probs(0.15, 0.25, 0.15)
        });
        let ring_results = drive(collectives, shapes, rounds, seed)?;

        for (rank, (a, b)) in reference.iter().zip(&ring_results).enumerate() {
            for (round, (ra, rb)) in a.iter().zip(b).enumerate() {
                prop_assert!(
                    bits(ra) == bits(rb),
                    "rank {rank} round {round}: ring result diverged from in-proc"
                );
            }
        }
        for (i, ib) in inboxes.iter().enumerate() {
            prop_assert!(
                ib.open_chunks() == 0,
                "ring inbox {i} must drain after the rounds"
            );
        }
        Ok(())
    });
}

/// Run `rounds` rounds in which every rank first reduces monolithically,
/// then bucketed at each bound in `bucket_sizes`; returns, per rank and
/// round, the monolithic result followed by each bucketed result.
fn drive_bucketed(
    collectives: Vec<Arc<Collective>>,
    shapes: Vec<usize>,
    rounds: usize,
    seed: u64,
    bucket_sizes: Vec<usize>,
) -> Result<Vec<Vec<Vec<ParamSet>>>, String> {
    let handles: Vec<_> = collectives
        .into_iter()
        .enumerate()
        .map(|(rank, col)| {
            let shapes = shapes.clone();
            let bucket_sizes = bucket_sizes.clone();
            std::thread::spawn(move || -> Result<Vec<Vec<ParamSet>>, String> {
                (0..rounds)
                    .map(|round| {
                        let set = operand(&shapes, rank, round, seed);
                        let mut results = vec![col
                            .all_reduce_mean(rank, &set)
                            .map_err(|e| format!("rank {rank} round {round} mono: {e:#}"))?];
                        for &bb in &bucket_sizes {
                            results.push(
                                col.all_reduce_mean_bucketed(rank, set.clone(), bb).map_err(
                                    |e| format!("rank {rank} round {round} bucket {bb}: {e:#}"),
                                )?,
                            );
                        }
                        Ok(results)
                    })
                    .collect()
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().map_err(|_| "rank panicked".to_string())?)
        .collect()
}

#[test]
fn bucketed_allreduce_bitwise_matches_monolithic_across_backends() {
    // The bucketed/overlapped tentpole invariant: for bucket bounds smaller
    // than one tensor, mid-sized, and >= the whole set, the async bucketed
    // reduce must reproduce the monolithic rank-order fold bit-for-bit on
    // the in-proc backend AND on both RPC backends under drops/duplicates.
    prop::check_n("bucketed-allreduce-bitwise", 12, |rng| {
        let world = 2 + rng.below(2); // 2..=3 ranks
        let rounds = 1 + rng.below(2);
        // several tensors so sub-tensor bounds really split the set
        let shapes: Vec<usize> = (0..2 + rng.below(3)).map(|_| 1 + rng.below(24)).collect();
        let seed = rng.next_u64();
        // smaller than one tensor / mid / >= whole set
        let bucket_sizes = vec![4, 64, 1 << 20];

        // in-proc reference: monolithic + bucketed must all agree
        let inproc = Collective::new(world);
        let reference = drive_bucketed(
            (0..world).map(|_| inproc.clone()).collect(),
            shapes.clone(),
            rounds,
            seed,
            bucket_sizes.clone(),
        )?;
        for (rank, per_round) in reference.iter().enumerate() {
            for (round, results) in per_round.iter().enumerate() {
                for (i, r) in results[1..].iter().enumerate() {
                    prop_assert!(
                        bits(r) == bits(&results[0]),
                        "rank {rank} round {round}: in-proc bucketed #{i} diverged"
                    );
                }
            }
        }

        // rendezvous RPC backend under faults
        let server = RendezvousHost::serve(world);
        let rpc_cols: Vec<Arc<Collective>> = (0..world)
            .map(|rank| {
                let flaky = FlakyTransport::new(
                    InProcTransport::new(server.clone()),
                    seed ^ (0xBCE7 + rank as u64),
                )
                .with_probs(0.1, 0.2, 0.1);
                Collective::with_backend(Arc::new(
                    RpcCollective::new(flaky, world)
                        .with_retry(RetryPolicy::exponential(256, Duration::from_micros(10)))
                        .with_round_timeout(Duration::from_secs(60)),
                ))
            })
            .collect();
        let rpc_results =
            drive_bucketed(rpc_cols, shapes.clone(), rounds, seed, bucket_sizes.clone())?;

        // ring backend under faults, tiny chunks
        let (_inboxes, ring_cols) = ring_group(world, 16, |rank, server| {
            FlakyTransport::new(
                InProcTransport::new(server),
                seed ^ (0x51B6u64.wrapping_add(rank as u64)),
            )
            .with_probs(0.1, 0.2, 0.1)
        });
        let ring_results = drive_bucketed(ring_cols, shapes, rounds, seed, bucket_sizes)?;

        for (backend, results) in [("rpc", &rpc_results), ("ring", &ring_results)] {
            for (rank, (a, b)) in reference.iter().zip(results).enumerate() {
                for (round, (ra, rb)) in a.iter().zip(b).enumerate() {
                    for (i, (xa, xb)) in ra.iter().zip(rb).enumerate() {
                        prop_assert!(
                            bits(xa) == bits(xb),
                            "rank {rank} round {round} result #{i}: {backend} diverged"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn broadcast_bytes_survives_faults_on_every_backend() {
    // the weight-broadcast channel: root's payload must arrive bit-exact on
    // every rank, over the rendezvous RPC and ring backends under faults
    let world = 3;
    let payload: Vec<u8> = (0..4096u32)
        .flat_map(|i| i.wrapping_mul(2654435761).to_le_bytes())
        .collect();

    let run = |cols: Vec<Arc<Collective>>| -> Vec<Vec<u8>> {
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                let p = payload.clone();
                std::thread::spawn(move || {
                    let mine = if rank == 2 { p } else { Vec::new() };
                    col.broadcast_bytes(rank, 2, mine).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };

    let server = RendezvousHost::serve(world);
    let rpc_cols: Vec<Arc<Collective>> = (0..world)
        .map(|rank| {
            let flaky =
                FlakyTransport::new(InProcTransport::new(server.clone()), 0xB0 + rank as u64)
                    .with_probs(0.15, 0.25, 0.15);
            Collective::with_backend(Arc::new(
                RpcCollective::new(flaky, world)
                    .with_retry(RetryPolicy::exponential(256, Duration::from_micros(10))),
            ))
        })
        .collect();
    for got in run(rpc_cols) {
        assert_eq!(got, payload, "rpc broadcast corrupted the payload");
    }

    let (_inboxes, ring_cols) = ring_group(world, 64, |rank, server| {
        FlakyTransport::new(InProcTransport::new(server), 0xB1D6 + rank as u64)
            .with_probs(0.15, 0.25, 0.15)
    });
    for got in run(ring_cols) {
        assert_eq!(got, payload, "ring broadcast corrupted the payload");
    }
}

#[test]
fn ring_full_surface_over_real_tcp_matches_inproc() {
    // scalars + tokens + barrier + params across 4 ranks over a real
    // loopback-TCP ring
    let world = 4;
    let inproc = Collective::new(world);
    let (_hosts, ring) = gcore::launch::ring_tcp_group(world, 64).unwrap();

    type Surface = (Vec<f64>, Vec<Vec<Vec<i32>>>, ParamSet);
    let run_group = |collectives: Vec<Arc<Collective>>| -> Vec<Surface> {
        let handles: Vec<_> = collectives
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || {
                    col.barrier(rank).unwrap();
                    let scalars = col
                        .mean_scalars(rank, vec![rank as f64, 0.1 * rank as f64])
                        .unwrap();
                    let tokens = col
                        .gather_tokens(rank, vec![vec![rank as i32; rank + 1]])
                        .unwrap();
                    let set = operand(&[33], rank, 0, 77);
                    let reduced = col.all_reduce_mean(rank, &set).unwrap();
                    col.barrier(rank).unwrap();
                    (scalars, tokens, reduced)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };

    let a = run_group((0..world).map(|_| inproc.clone()).collect());
    let b = run_group(ring);
    for (rank, ((sa, ta, pa), (sb, tb, pb))) in a.iter().zip(&b).enumerate() {
        let sa_bits: Vec<u64> = sa.iter().map(|f| f.to_bits()).collect();
        let sb_bits: Vec<u64> = sb.iter().map(|f| f.to_bits()).collect();
        assert_eq!(sa_bits, sb_bits, "rank {rank} scalars diverged");
        assert_eq!(ta, tb, "rank {rank} tokens diverged");
        assert_eq!(bits(pa), bits(pb), "rank {rank} params diverged");
    }
}

#[test]
fn faults_are_actually_injected_and_absorbed() {
    // A fixed heavy-fault run that also checks the transport really dropped
    // things (so the property above isn't vacuously passing).
    let world = 3;
    let server = RendezvousHost::serve(world);
    let transports: Vec<_> = (0..world)
        .map(|rank| {
            Arc::new(
                FlakyTransport::new(InProcTransport::new(server.clone()), 777 + rank as u64)
                    .with_probs(0.25, 0.35, 0.25),
            )
        })
        .collect();
    let collectives: Vec<Arc<Collective>> = transports
        .iter()
        .map(|t| {
            let backend = RpcCollective::new(t.clone(), world)
                .with_retry(RetryPolicy::exponential(512, Duration::from_micros(10)));
            Collective::with_backend(Arc::new(backend))
        })
        .collect();
    let results = drive(collectives, vec![16, 5], 4, 42).unwrap();
    for r in &results[1..] {
        for (a, b) in results[0].iter().zip(r) {
            assert_eq!(bits(a), bits(b), "all ranks must agree");
        }
    }
    let injected: u64 = transports
        .iter()
        .map(|t| t.injected_failures.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert!(injected > 0, "fault profile must actually fire");
    assert_eq!(server.stats().cached_now, 0);
    assert_eq!(server.service().open_rounds(), 0);
}

#[test]
fn full_collective_surface_over_real_tcp_matches_inproc() {
    // scalars + tokens + barrier + params across 4 ranks over loopback TCP
    let world = 4;
    let inproc = Collective::new(world);
    let host = TcpRpcHost::spawn(RendezvousHost::serve(world)).unwrap();
    let tcp: Vec<Arc<Collective>> = (0..world)
        .map(|_| {
            Collective::with_backend(Arc::new(RpcCollective::new(
                TcpTransport::connect(host.addr),
                world,
            )))
        })
        .collect();

    let run_group = |collectives: Vec<Arc<Collective>>| -> Vec<(Vec<f64>, Vec<Vec<Vec<i32>>>)> {
        let handles: Vec<_> = collectives
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || {
                    col.barrier(rank).unwrap();
                    let scalars = col
                        .mean_scalars(rank, vec![rank as f64, 0.1 * rank as f64])
                        .unwrap();
                    let tokens = col
                        .gather_tokens(rank, vec![vec![rank as i32; rank + 1]])
                        .unwrap();
                    col.barrier(rank).unwrap();
                    (scalars, tokens)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };

    let a = run_group((0..world).map(|_| inproc.clone()).collect());
    let b = run_group(tcp);
    for (rank, ((sa, ta), (sb, tb))) in a.iter().zip(&b).enumerate() {
        let sa_bits: Vec<u64> = sa.iter().map(|f| f.to_bits()).collect();
        let sb_bits: Vec<u64> = sb.iter().map(|f| f.to_bits()).collect();
        assert_eq!(sa_bits, sb_bits, "rank {rank} scalars diverged");
        assert_eq!(ta, tb, "rank {rank} tokens diverged");
    }
    drop(host);
}

/// A lease-armed rendezvous server plus one fault-injected heartbeat per
/// rank (interval ≪ TTL).  The tight retry policy keeps a lossy renewal
/// well inside one TTL, so drops must never read as death.
fn lease_server_with_beats(
    world: usize,
    ttl: Duration,
    seed: u64,
) -> (Arc<gcore::rpc::server::RpcServer<RendezvousHost>>, Vec<Heartbeat>) {
    let server = Arc::new(gcore::rpc::server::RpcServer::new(
        RendezvousHost::new(world).with_lease_ttl(ttl),
    ));
    let beats = (0..world)
        .map(|rank| {
            let flaky = FlakyTransport::new(
                InProcTransport::new(server.clone()),
                seed ^ (0x8EA7 + rank as u64),
            )
            .with_probs(0.2, 0.25, 0.2);
            Heartbeat::start(
                RpcClient::new(flaky)
                    .with_retry(RetryPolicy::exponential(64, Duration::from_micros(50))),
                rank as u32,
                0,
                Duration::from_millis(10),
            )
        })
        .collect();
    (server, beats)
}

#[test]
fn heartbeats_through_faults_never_read_as_death_below_ttl() {
    // No false positives: as long as every rank keeps beating — even
    // through a transport dropping ~40% of deliveries — no lease may
    // lapse, across several full TTL windows.
    prop::check_n("lease-no-false-death", 6, |rng| {
        let world = 2 + rng.below(2); // 2..=3 ranks
        let ttl = Duration::from_millis(150 + 10 * rng.below(10) as u64);
        let seed = rng.next_u64();
        let (server, beats) = lease_server_with_beats(world, ttl, seed);
        std::thread::sleep(ttl * 3);
        prop_assert!(
            server.service().dead_rank().is_none(),
            "a live, beating rank was declared dead below the TTL"
        );
        // and the group still completes a faultless collective round
        let cols: Vec<Arc<Collective>> = (0..world)
            .map(|_| {
                Collective::with_backend(Arc::new(RpcCollective::new(
                    InProcTransport::new(server.clone()),
                    world,
                )))
            })
            .collect();
        drive(cols, vec![8], 1, seed)?;
        drop(beats);
        Ok(())
    });
}

#[test]
fn lease_expiry_fans_out_promptly_as_typed_peer_dead() {
    // One rank goes silent; every survivor blocked in a collective round
    // must get a typed PeerDead well under the 300 s round timeout — in
    // TTL-scale time — even with faults on the survivors' transports.
    prop::check_n("lease-prompt-peer-dead", 5, |rng| {
        let world = 2 + rng.below(2); // 2..=3 ranks
        let ttl = Duration::from_millis(80 + 10 * rng.below(8) as u64);
        let seed = rng.next_u64();
        let (server, mut beats) = lease_server_with_beats(world, ttl, seed);

        // the crash: the last rank's heartbeat thread stops (Drop joins it)
        let victim = world - 1;
        std::thread::sleep(Duration::from_millis(20));
        drop(beats.pop());

        let t0 = Instant::now();
        let handles: Vec<_> = (0..world - 1)
            .map(|rank| {
                let server = server.clone();
                std::thread::spawn(move || {
                    let flaky = FlakyTransport::new(
                        InProcTransport::new(server),
                        seed ^ (0xDEAD + rank as u64),
                    )
                    .with_probs(0.15, 0.2, 0.15);
                    let col = RpcCollective::new(flaky, world)
                        .with_retry(RetryPolicy::exponential(256, Duration::from_micros(10)))
                        .with_round_timeout(Duration::from_secs(60));
                    col.exchange(rank, "doomed", vec![rank as u8])
                })
            })
            .collect();
        for h in handles {
            let err = match h.join().unwrap() {
                Ok(_) => return Err("round completed without the victim".to_string()),
                Err(e) => e,
            };
            let status = CollectiveStatus::classify_error(&err);
            prop_assert!(
                matches!(status, Some(CollectiveStatus::PeerDead { rank }) if rank == victim as u32),
                "survivor failed without a typed PeerDead({victim}): {err:#}"
            );
        }
        let elapsed = t0.elapsed();
        prop_assert!(
            elapsed < ttl * 20 + Duration::from_secs(5),
            "fanout took {elapsed:?} for a {ttl:?} lease — not TTL-scale"
        );
        drop(beats);
        Ok(())
    });
}

#[test]
fn backend_world_size_is_consistent() {
    let server = RendezvousHost::serve(5);
    assert_eq!(server.service().world_size(), 5);
    let backend = RpcCollective::new(InProcTransport::new(server), 5);
    assert_eq!(backend.world_size(), 5);
    assert_eq!(Collective::with_backend(Arc::new(backend)).world_size(), 5);
}
