//! End-to-end coordinator integration over the tiny artifact set: the
//! generation engine, reward paths, parallel controllers, and a short
//! RLHF run that must actually move the policy.

use std::sync::Arc;

use gcore::config::RunConfig;
use gcore::coordinator::collective::Collective;
use gcore::coordinator::controller::Controller;
use gcore::coordinator::generation::{self, SamplerConfig};
use gcore::coordinator::pretrain;
use gcore::data::tasks::{TaskGen, TaskKind};
use gcore::data::tokenizer;
use gcore::launch;
use gcore::reward::{RewardKind, Rewarder, VerdictMode};
use gcore::runtime::{init_policy, Engine};
use gcore::util::rng::Rng;

/// Loads the tiny artifact set.  PANICS when the set is missing: the
/// fixture set is checked in (rust/tests/fixtures/artifacts/tiny) and the
/// interpreter backend is always available, so there is no legitimate
/// skip reason left — the tier fails loudly if either regresses.
fn engine() -> Arc<Engine> {
    match Engine::try_load("tiny") {
        Some(e) => Arc::new(e),
        None => panic!(
            "tiny artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        ),
    }
}

fn tiny_cfg() -> RunConfig {
    RunConfig {
        artifacts: "tiny".into(),
        world: 1,
        steps: 3,
        group_size: 4,
        sft_steps: 4,
        temperature: 1.0,
        // matches the sampler parameters baked into the fixture sets'
        // generate_rollout artifact, so controller rollouts take the fused
        // single-call path
        top_k: 16,
        ..RunConfig::default()
    }
}

#[test]
fn generation_respects_artifact_contract() {
    let e = engine();
    let dims = e.manifest().dims.clone();
    let params = init_policy(&e, 0).unwrap();
    let mut gen = TaskGen::new(vec![TaskKind::Add], 1);
    let prompts: Vec<Vec<i32>> = gen
        .sample_n(dims.batch)
        .iter()
        .map(|t| t.prompt_tokens(dims.prompt_len).unwrap())
        .collect();
    let mut rng = Rng::new(2);
    let out = generation::generate(
        &e,
        &params,
        &prompts,
        &SamplerConfig::default(),
        &mut rng,
    )
    .unwrap();
    assert_eq!(out.rows.len(), dims.batch);
    for (i, (row, (glen, mask))) in out
        .rows
        .iter()
        .zip(out.gen_lens.iter().zip(&out.masks))
        .enumerate()
    {
        assert_eq!(row.len(), dims.max_seq);
        assert!(*glen >= 1 && *glen <= dims.gen_len());
        // prompt is preserved verbatim
        assert_eq!(&row[..dims.prompt_len], &prompts[i][..]);
        // mask covers exactly the generated span
        let m: f32 = mask.iter().sum();
        assert_eq!(m as usize, *glen);
        assert!(mask[..dims.prompt_len].iter().all(|&x| x == 0.0));
    }
}

#[test]
fn greedy_generation_is_deterministic() {
    let e = engine();
    let dims = e.manifest().dims.clone();
    let params = init_policy(&e, 3).unwrap();
    let mut gen = TaskGen::new(vec![TaskKind::Copy], 4);
    let prompts: Vec<Vec<i32>> = gen
        .sample_n(dims.batch)
        .iter()
        .map(|t| t.prompt_tokens(dims.prompt_len).unwrap())
        .collect();
    let cfg = SamplerConfig { temperature: 0.0, top_k: 1, stop_at_eos: true };
    let a = generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(1)).unwrap();
    let b = generation::generate(&e, &params, &prompts, &cfg, &mut Rng::new(99)).unwrap();
    assert_eq!(a.rows, b.rows, "greedy decode must not depend on the rng");
}

#[test]
fn ground_truth_rewarder_scores_correctness() {
    let e = engine();
    let dims = e.manifest().dims.clone();
    let mut gen = TaskGen::new(vec![TaskKind::Add], 5);
    let tasks = gen.sample_n(dims.batch);
    // fabricate rows: half correct, half wrong
    let mut rows = Vec::new();
    let mut lens = Vec::new();
    for (i, t) in tasks.iter().enumerate() {
        let mut row = t.prompt_tokens(dims.prompt_len).unwrap();
        let answer = if i % 2 == 0 { t.answer.clone() } else { "9999".into() };
        row.extend(tokenizer::encode(&format!("{answer}\n")));
        lens.push(row.len() - dims.prompt_len);
        row.resize(dims.max_seq, tokenizer::PAD);
        rows.push(row);
    }
    let masks = vec![vec![1.0; dims.max_seq]; dims.batch];
    let out = generation::GenOutput { rows, gen_lens: lens, masks };
    let rewarder = Rewarder::ground_truth();
    let scores = rewarder.score(&e, &tasks, &out).unwrap();
    for (i, s) in scores.iter().enumerate() {
        assert_eq!(*s, if i % 2 == 0 { 1.0 } else { 0.0 }, "row {i}");
    }
}

#[test]
fn bt_pretraining_fits_preferences() {
    let e = engine();
    let (params, rep) =
        pretrain::train_bt(&e, vec![TaskKind::Copy, TaskKind::Rev], 60, 2e-3, 7).unwrap();
    assert_eq!(params.num_elements(), e.manifest().scalar_param_count);
    assert!(
        rep.final_metric >= 0.75,
        "BT pairwise accuracy {} should reach 0.75",
        rep.final_metric
    );
    assert!(rep.losses.last().unwrap() < rep.losses.first().unwrap());
}

#[test]
fn verifier_pretraining_beats_chance() {
    let e = engine();
    let (params, rep) =
        pretrain::train_verifier(&e, vec![TaskKind::Copy], 300, 3e-3, 11).unwrap();
    assert_eq!(params.num_elements(), e.manifest().param_count);
    assert!(
        rep.final_metric > 0.65,
        "verifier accuracy {} should clearly beat chance",
        rep.final_metric
    );
}

#[test]
fn rlhf_single_controller_short_run() {
    let _e = engine();
    let cfg = tiny_cfg();
    let report = launch::run_training(&cfg).unwrap();
    assert_eq!(report.steps.len(), cfg.steps);
    // SFT warm-start must reduce loss
    let sft = &report.sft_losses;
    assert!(sft.last().unwrap() < sft.first().unwrap(), "{sft:?}");
    for s in &report.steps {
        assert!(s.loss.is_finite());
        assert!((0.0..=1.0).contains(&s.accuracy), "{s:?}");
        assert!(s.mean_gen_len >= 1.0);
        assert_eq!(s.gen_rounds, 1.0); // no dynamic sampling configured
    }
    assert!(!report.timers_markdown.is_empty());
}

#[test]
fn rlhf_two_parallel_controllers_agree_with_collective() {
    // world=2: gradients all-reduce; stats are identical across ranks by
    // construction (mean_scalars) — the run must simply succeed and train.
    let _e = engine();
    let cfg = RunConfig { world: 2, steps: 2, sft_steps: 2, ..tiny_cfg() };
    let report = launch::run_training(&cfg).unwrap();
    assert_eq!(report.steps.len(), 2);
    assert!(report.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn dynamic_sampling_loops_locally() {
    let _e = engine();
    let cfg = RunConfig {
        dynamic_sampling: true,
        max_resample_rounds: 3,
        steps: 2,
        sft_steps: 2,
        ..tiny_cfg()
    };
    let report = launch::run_training(&cfg).unwrap();
    for s in &report.steps {
        assert!((1.0..=3.0).contains(&s.gen_rounds), "{s:?}");
    }
}

#[test]
fn generative_reward_path_runs() {
    let e = engine();
    let cfg = RunConfig {
        reward: RewardKind::Generative,
        verdict_mode: VerdictMode::Logit,
        verifier_sft_steps: 10,
        steps: 1,
        sft_steps: 1,
        ..tiny_cfg()
    };
    // build rewarder through the launcher path
    let (rewarder, metric) = launch::build_rewarder(&e, &cfg).unwrap();
    assert!(metric > 0.0);
    let collective = Collective::new(1);
    let policy = init_policy(&e, cfg.seed as u32).unwrap();
    let mut c = Controller::new(0, e, collective, cfg, policy, rewarder).unwrap();
    let stats = c.rlhf_step(0).unwrap();
    assert!(stats.loss.is_finite());
    assert!((0.0..=1.0).contains(&stats.mean_reward));
}

#[test]
fn regex_verdict_mode_runs() {
    let e = engine();
    let dims = e.manifest().dims.clone();
    let (params, _) = pretrain::train_verifier(&e, vec![TaskKind::Add], 10, 2e-3, 13).unwrap();
    let mut gen = TaskGen::new(vec![TaskKind::Add], 14);
    let tasks = gen.sample_n(dims.batch);
    let responses: Vec<String> = tasks.iter().map(|t| t.answer.clone()).collect();
    let scores = gcore::reward::score_generative(
        &e,
        &params,
        &tasks,
        &responses,
        VerdictMode::Regex,
    )
    .unwrap();
    assert_eq!(scores.len(), dims.batch);
    assert!(scores.iter().all(|&s| s == 0.0 || s == 1.0));
}
