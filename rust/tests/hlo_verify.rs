//! Static-verifier tier: a malformed-HLO corpus with golden diagnostics,
//! plus property tests over the checked-in fixture artifact sets — every
//! instruction's inferred shape must equal its declared shape, both sets
//! must lint clean (the same gate `gcore hlo-lint` enforces in CI), and
//! the planner's static peak-live bound for `decode_step` must sit inside
//! the 3 MiB/token allocation budget `tests/alloc_counts.rs` asserts
//! dynamically.

use gcore::runtime::hlo::parser::HloModule;
use gcore::runtime::hlo::plan::StaticPlan;
use gcore::runtime::hlo::verify::{self, DiagKind, Diagnostic};
use gcore::runtime::hlo::Program;
use gcore::runtime::{artifacts_dir, Manifest};

fn diags(text: &str) -> Vec<Diagnostic> {
    let (_, d) = verify::verify_text(text);
    d
}

/// The corpus asserts one *specific* golden diagnostic per case: kind,
/// instruction anchor, and the message fragments an operator needs to fix
/// the artifact without opening the HLO.
fn assert_golden(ds: &[Diagnostic], kind: DiagKind, instr: &str, fragments: &[&str]) {
    assert_eq!(ds.len(), 1, "expected exactly one diagnostic, got {ds:?}");
    let d = &ds[0];
    assert_eq!(d.kind, kind, "{d}");
    assert_eq!(d.instr, instr, "{d}");
    for f in fragments {
        assert!(d.message.contains(f), "missing {f:?} in: {d}");
    }
}

// ---------------------------------------------------------------------------
// malformed corpus
// ---------------------------------------------------------------------------

#[test]
fn shape_mismatch_reports_instruction_opcode_and_both_shapes() {
    let ds = diags(
        "ENTRY %m (x: f32[2,3], y: f32[2,3]) -> (f32[2,4]) {\n  \
         %x = f32[2,3] parameter(0)\n  \
         %y = f32[2,3] parameter(1)\n  \
         %s = f32[2,4] add(f32[2,3] %x, f32[2,3] %y)\n  \
         ROOT %t = (f32[2,4]) tuple(f32[2,4] %s)\n}\n",
    );
    assert_golden(&ds, DiagKind::ShapeMismatch, "s", &["f32[2,4]", "f32[2,3]"]);
    assert_eq!(ds[0].opcode, "add");
    let rendered = ds[0].to_string();
    assert!(rendered.contains("[shape-mismatch]"), "{rendered}");
    assert!(rendered.contains("%s (add)"), "{rendered}");
}

#[test]
fn undefined_operand_is_a_parse_diagnostic_naming_the_operand() {
    let ds = diags(
        "ENTRY %m (x: f32[2]) -> (f32[2]) {\n  \
         %x = f32[2] parameter(0)\n  \
         %n = f32[2] negate(f32[2] %ghost)\n  \
         ROOT %t = (f32[2]) tuple(f32[2] %n)\n}\n",
    );
    assert_eq!(ds.len(), 1, "{ds:?}");
    assert_eq!(ds[0].kind, DiagKind::ParseError);
    assert!(ds[0].message.contains("ghost"), "{}", ds[0]);
}

#[test]
fn bad_reduce_body_is_a_bad_reduce_diagnostic() {
    // body folds with multiply — not one of the evaluator's add/max/min
    let ds = diags(
        "%rmul (a: f32[], b: f32[]) -> f32[] {\n  \
         %a = f32[] parameter(0)\n  \
         %b = f32[] parameter(1)\n  \
         ROOT %r = f32[] multiply(f32[] %a, f32[] %b)\n}\n\n\
         ENTRY %m (x: f32[2,3]) -> (f32[2]) {\n  \
         %x = f32[2,3] parameter(0)\n  \
         %z = f32[] constant(0)\n  \
         %s = f32[2] reduce(f32[2,3] %x, f32[] %z), dimensions={1}, to_apply=%rmul\n  \
         ROOT %t = (f32[2]) tuple(f32[2] %s)\n}\n",
    );
    assert_golden(
        &ds,
        DiagKind::BadReduce,
        "s",
        &["reduce body", "rmul", "multiply", "supported fold"],
    );
}

#[test]
fn reduce_body_dtype_mismatch_is_a_bad_reduce_diagnostic() {
    // s32 reduce folded through an f32 body
    let ds = diags(
        "%radd (a: f32[], b: f32[]) -> f32[] {\n  \
         %a = f32[] parameter(0)\n  \
         %b = f32[] parameter(1)\n  \
         ROOT %r = f32[] add(f32[] %a, f32[] %b)\n}\n\n\
         ENTRY %m (x: s32[4]) -> (s32[]) {\n  \
         %x = s32[4] parameter(0)\n  \
         %z = s32[] constant(0)\n  \
         %s = s32[] reduce(s32[4] %x, s32[] %z), dimensions={0}, to_apply=%radd\n  \
         ROOT %t = (s32[]) tuple(s32[] %s)\n}\n",
    );
    assert_golden(&ds, DiagKind::BadReduce, "s", &["radd", "expected s32[]"]);
}

#[test]
fn documented_gap_opcode_is_a_structured_unsupported_op() {
    let ds = diags(
        "ENTRY %m (x: f32[2]) -> (f32[2]) {\n  \
         %x = f32[2] parameter(0)\n  \
         %w = f32[2] conditional(f32[2] %x)\n  \
         ROOT %t = (f32[2]) tuple(f32[2] %w)\n}\n",
    );
    assert_golden(
        &ds,
        DiagKind::UnsupportedOp,
        "w",
        &["'conditional'", "documented op-set gap", "ROADMAP.md"],
    );
}

#[test]
fn dtype_mismatched_select_is_a_dtype_diagnostic() {
    let ds = diags(
        "ENTRY %m (p: pred[2], a: f32[2], b: s32[2]) -> (f32[2]) {\n  \
         %p = pred[2] parameter(0)\n  \
         %a = f32[2] parameter(1)\n  \
         %b = s32[2] parameter(2)\n  \
         %s = f32[2] select(pred[2] %p, f32[2] %a, s32[2] %b)\n  \
         ROOT %t = (f32[2]) tuple(f32[2] %s)\n}\n",
    );
    assert_golden(
        &ds,
        DiagKind::DtypeMismatch,
        "s",
        &["select branch dtypes differ", "f32", "s32"],
    );
}

#[test]
fn dead_instruction_is_a_def_use_diagnostic() {
    let ds = diags(
        "ENTRY %m (x: f32[2]) -> (f32[2]) {\n  \
         %x = f32[2] parameter(0)\n  \
         %dead = f32[2] negate(f32[2] %x)\n  \
         %n = f32[2] negate(f32[2] %x)\n  \
         ROOT %t = (f32[2]) tuple(f32[2] %n)\n}\n",
    );
    assert_golden(&ds, DiagKind::DefUse, "dead", &["never used"]);
}

#[test]
fn silent_defaults_are_now_hard_diagnostics() {
    // concatenate without dimensions= used to default to axis 0
    let ds = diags(
        "ENTRY %m (x: f32[2], y: f32[2]) -> (f32[4]) {\n  \
         %x = f32[2] parameter(0)\n  \
         %y = f32[2] parameter(1)\n  \
         %c = f32[4] concatenate(f32[2] %x, f32[2] %y)\n  \
         ROOT %t = (f32[4]) tuple(f32[4] %c)\n}\n",
    );
    assert_golden(
        &ds,
        DiagKind::BadAttribute,
        "c",
        &["concatenate without dimensions=", "no silent axis-0 default"],
    );

    // dot without dimension numbers used to default to an outer product
    let ds = diags(
        "ENTRY %m (x: f32[2,3], y: f32[3,4]) -> (f32[2,4]) {\n  \
         %x = f32[2,3] parameter(0)\n  \
         %y = f32[3,4] parameter(1)\n  \
         %d = f32[2,4] dot(f32[2,3] %x, f32[3,4] %y)\n  \
         ROOT %t = (f32[2,4]) tuple(f32[2,4] %d)\n}\n",
    );
    assert_golden(
        &ds,
        DiagKind::BadAttribute,
        "d",
        &["dot without dimension numbers", "no silent default"],
    );
}

#[test]
fn program_compile_refuses_unverified_modules() {
    let msg = format!(
        "{:#}",
        Program::parse(
            "ENTRY %m (x: f32[2]) -> (f32[3]) {\n  \
             %x = f32[2] parameter(0)\n  \
             %n = f32[3] negate(f32[2] %x)\n  \
             ROOT %t = (f32[3]) tuple(f32[3] %n)\n}\n",
        )
        .unwrap_err()
    );
    assert!(msg.contains("failed static verification"), "{msg}");
    assert!(msg.contains("%n"), "{msg}");
    assert!(msg.contains("f32[3]") && msg.contains("f32[2]"), "{msg}");
}

// ---------------------------------------------------------------------------
// fixture property tests
// ---------------------------------------------------------------------------

fn fixture_sets() -> Vec<(String, Manifest)> {
    ["tiny", "synthetic"]
        .iter()
        .map(|set| {
            let dir = artifacts_dir(set);
            assert!(
                dir.join("manifest.json").exists(),
                "checked-in fixture set '{set}' missing at {dir:?} — \
                 regenerate with `python -m compile.fixturegen`"
            );
            (set.to_string(), Manifest::load(&dir).unwrap())
        })
        .collect()
}

#[test]
fn every_fixture_instruction_inferred_shape_equals_declared() {
    let mut checked = 0usize;
    for (set, manifest) in fixture_sets() {
        for name in manifest.artifacts.keys() {
            let text = std::fs::read_to_string(manifest.hlo_path(name).unwrap()).unwrap();
            let m = HloModule::parse(&text).unwrap();
            for c in &m.computations {
                for (i, ins) in c.instrs.iter().enumerate() {
                    let inferred = verify::infer_shape(&m, c, i).unwrap_or_else(|e| {
                        panic!("{set}/{name} %{}: {e:#}", ins.name)
                    });
                    assert_eq!(
                        inferred.as_ref(),
                        ins.shape.as_ref(),
                        "{set}/{name} %{} ({})",
                        ins.name,
                        ins.opcode
                    );
                    checked += 1;
                }
            }
        }
    }
    // the property is vacuous if the walk goes wrong; both sets together
    // carry north of 9k instructions
    assert!(checked > 9000, "only {checked} instructions walked");
}

#[test]
fn checked_in_fixture_sets_lint_clean() {
    for (set, manifest) in fixture_sets() {
        let report = verify::lint_set(&manifest.dir).unwrap();
        let all: Vec<String> = report
            .artifacts
            .iter()
            .flat_map(|a| a.diagnostics.iter().map(move |d| format!("{}: {d}", a.name)))
            .collect();
        assert!(
            report.total_diagnostics() == 0,
            "fixture set '{set}' must lint clean:\n{}",
            all.join("\n")
        );
        for a in &report.artifacts {
            let plan = a.plan.as_ref().unwrap_or_else(|| {
                panic!("{set}/{} verified clean but has no plan", a.name)
            });
            assert_eq!(plan.last_use.len(), a.instrs);
            assert!(plan.peak_live_bytes > 0, "{set}/{}", a.name);
        }
    }
}

#[test]
fn decode_step_static_peak_fits_the_alloc_budget() {
    // tests/alloc_counts.rs asserts < 3 MiB allocated per decoded token at
    // runtime; the static bound must agree, or the planner's model and the
    // allocator have drifted apart
    for (set, manifest) in fixture_sets() {
        let text =
            std::fs::read_to_string(manifest.hlo_path("decode_step").unwrap()).unwrap();
        let program = Program::parse(&text).unwrap();
        let peak = program.plan().peak_live_bytes;
        assert!(
            peak < 3 << 20,
            "{set}/decode_step static peak {peak} bytes exceeds the 3 MiB budget"
        );
    }
}

#[test]
fn fixture_plans_pin_root_operands_live() {
    for (_, manifest) in fixture_sets() {
        let text =
            std::fs::read_to_string(manifest.hlo_path("decode_step").unwrap()).unwrap();
        let m = HloModule::parse(&text).unwrap();
        let plan = StaticPlan::build(&m);
        let entry = m.entry_computation();
        assert_eq!(plan.last_use[entry.root], usize::MAX);
        for &op in &entry.instrs[entry.root].operands {
            assert_eq!(plan.last_use[op], usize::MAX, "root operand dropped early");
        }
        // decode's elementwise body yields fusible chains — the report must
        // see them, and each chain link must be a real instruction index
        assert!(!plan.fusible_chains.is_empty());
        for chain in &plan.fusible_chains {
            assert!(chain.len() >= 2);
            assert!(chain.iter().all(|&i| i < entry.instrs.len()));
        }
    }
}
