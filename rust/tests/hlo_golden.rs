//! Golden-transcript tests for the pure-Rust HLO interpreter backend.
//!
//! For every committed fixture artifact with a golden file, inputs are
//! re-derived from the deterministic recipe (`golden_input`, an exact
//! mirror of `python/compile/fixturegen/goldens.py::golden_input` — change
//! both or neither), evaluated through the engine, and compared against
//! the committed outputs.  Goldens were computed **with jax**
//! (`model.py`/`ref.py`) at fixture-generation time, so this tier
//! differentially tests the interpreter against jax on every CI run
//! without CI ever running Python.  `init_*` goldens come from the
//! fixturegen evaluator mirror instead (jax PRNG lowers to a custom-call).
//!
//! A `pjrt`-only differential test additionally asserts interp == PJRT on
//! the same artifacts; it is compiled out (not silently skipped) when the
//! feature is absent.

use std::path::PathBuf;

use gcore::runtime::{Engine, Tensor};
use gcore::util::json::Json;

/// Walk up from the cwd to a checked-in fixture path.
fn fixture_dir(rel: &str) -> PathBuf {
    let mut dir = std::env::current_dir().unwrap();
    loop {
        let cand = dir.join("rust/tests/fixtures").join(rel);
        if cand.exists() {
            return cand;
        }
        if !dir.pop() {
            panic!("fixture path rust/tests/fixtures/{rel} not found from cwd");
        }
    }
}

fn hash(i: usize, j: usize) -> u32 {
    ((i as u64)
        .wrapping_mul(1_000_003)
        .wrapping_add(j as u64) as u32)
        .wrapping_mul(2_654_435_761)
}

fn unit(u: u32) -> f64 {
    (u >> 8) as f64 / 16_777_216.0
}

/// Deterministic golden input for input slot `index` of an artifact.
/// EXACT mirror of fixturegen's `golden_input` (integer hash + f64 math,
/// rounded to f32 once).
fn golden_input(
    dims: &gcore::runtime::ModelDims,
    index: usize,
    name: &str,
    shape: &[usize],
    dtype: &str,
) -> Tensor {
    let n: usize = shape.iter().product();
    let base = name.rsplit('/').next().unwrap_or(name);
    match dtype {
        "u32" => Tensor::scalar_u32(42),
        "i32" => {
            if base == "pos" {
                return Tensor::scalar_i32(dims.prompt_len as i32);
            }
            let hi = if base.ends_with("idx") { dims.max_seq - 1 } else { dims.vocab };
            let vals: Vec<i32> =
                (0..n).map(|j| (hash(index, j) as usize % hi) as i32).collect();
            Tensor::i32(shape.to_vec(), vals)
        }
        _ => {
            let scalar = match base {
                "step" => Some(3.0f32),
                "lr" => Some(1e-3),
                "clip_eps" => Some(0.2),
                "kl_coef" => Some(0.03),
                "ent_coef" => Some(0.01),
                _ => None,
            };
            if let Some(v) = scalar {
                return Tensor::scalar_f32(v);
            }
            let vals: Vec<f32> = (0..n)
                .map(|j| {
                    let h = hash(index, j);
                    let u = unit(h);
                    let v: f64 = if name.starts_with("v/") {
                        1e-4 * u + 1e-8
                    } else if base == "mask" {
                        return if (h & 3) != 0 { 1.0f32 } else { 0.0 };
                    } else if base == "old_logp" || base == "ref_logp" {
                        -2.0 * u - 0.05
                    } else if matches!(base, "adv" | "returns" | "q" | "k" | "v") {
                        2.0 * u - 1.0
                    } else if base == "cache_k" || base == "cache_v" {
                        0.1 * u - 0.05
                    } else if matches!(base, "ln1_g" | "ln2_g" | "lnf_g") {
                        1.0 + 0.01 * (u - 0.5)
                    } else {
                        0.04 * u - 0.02
                    };
                    v as f32
                })
                .collect();
            Tensor::f32(shape.to_vec(), vals)
        }
    }
}

fn golden_inputs(engine: &Engine, artifact: &str) -> Vec<Tensor> {
    let spec = engine.manifest().artifact(artifact).unwrap().clone();
    let dims = engine.manifest().dims.clone();
    spec.inputs
        .iter()
        .enumerate()
        .map(|(i, s)| golden_input(&dims, i, &s.name, &s.shape, s.dtype.name()))
        .collect()
}

struct Golden {
    artifact: String,
    atol: f64,
    rtol: f64,
    outputs: Vec<Tensor>,
}

fn load_golden(path: &std::path::Path) -> Golden {
    let text = std::fs::read_to_string(path).unwrap();
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("{path:?}: {e:?}"));
    let outputs = j
        .req("outputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|o| {
            let shape: Vec<usize> = o
                .req("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect();
            let data = o.req("data").unwrap().as_arr().unwrap();
            match o.req("dtype").unwrap().as_str().unwrap() {
                "f32" => Tensor::f32(
                    shape,
                    data.iter().map(|v| v.as_f64().unwrap() as f32).collect(),
                ),
                "i32" => Tensor::i32(
                    shape,
                    data.iter().map(|v| v.as_i64().unwrap() as i32).collect(),
                ),
                "u32" => Tensor::u32(
                    shape,
                    data.iter().map(|v| v.as_i64().unwrap() as u32).collect(),
                ),
                other => panic!("bad golden dtype {other}"),
            }
        })
        .collect();
    Golden {
        artifact: j.req("artifact").unwrap().as_str().unwrap().to_string(),
        atol: j.req("atol").unwrap().as_f64().unwrap(),
        rtol: j.req("rtol").unwrap().as_f64().unwrap(),
        outputs,
    }
}

fn assert_close(artifact: &str, idx: usize, got: &Tensor, want: &Tensor, atol: f64, rtol: f64) {
    assert_eq!(got.shape, want.shape, "{artifact} output #{idx} shape");
    assert_eq!(got.dtype(), want.dtype(), "{artifact} output #{idx} dtype");
    match (&got.data, &want.data) {
        (gcore::runtime::TensorData::F32(a), gcore::runtime::TensorData::F32(b)) => {
            for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                let (x, y) = (*x as f64, *y as f64);
                assert!(
                    (x - y).abs() <= atol + rtol * y.abs(),
                    "{artifact} output #{idx}[{k}]: interp {x} vs golden {y} \
                     (atol {atol}, rtol {rtol})"
                );
            }
        }
        _ => assert_eq!(got, want, "{artifact} output #{idx} (integer)"),
    }
}

fn run_goldens(config: &str) {
    let engine = Engine::try_load(config).unwrap_or_else(|| {
        panic!(
            "{config} artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        )
    });
    let dir = fixture_dir(&format!("goldens/{config}"));
    let mut checked = 0;
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension() == Some(std::ffi::OsStr::new("json")))
        .collect();
    entries.sort();
    assert!(!entries.is_empty(), "no golden files in {dir:?}");
    for path in entries {
        let golden = load_golden(&path);
        let inputs = golden_inputs(&engine, &golden.artifact);
        let out = engine.run(&golden.artifact, &inputs).unwrap_or_else(|e| {
            panic!("running '{}' on golden inputs: {e:#}", golden.artifact)
        });
        assert_eq!(
            out.len(),
            golden.outputs.len(),
            "{}: output arity",
            golden.artifact
        );
        for (i, (g, w)) in out.iter().zip(&golden.outputs).enumerate() {
            assert_close(&golden.artifact, i, g, w, golden.atol, golden.rtol);
        }
        checked += 1;
    }
    println!("checked {checked} goldens for '{config}' (backend: {})", engine.backend_name());
}

/// Every synthetic-set artifact matches its jax-generated golden.
#[test]
fn synthetic_goldens_match_jax_references() {
    run_goldens("synthetic");
}

/// Tiny-set spot goldens (small-output artifacts) match jax references.
#[test]
fn tiny_goldens_match_jax_references() {
    run_goldens("tiny");
}

/// `convert` edge cases pinned against jax semantics.  jax lowers float→int
/// casts to the same saturating truncation Rust `as` performs: truncate
/// toward zero, NaN → 0, out-of-range saturates to the integer type's
/// min/max.  These goldens keep the interpreter from silently drifting to
/// a wrapping or UB-replicating cast.
#[test]
fn convert_edge_cases_match_jax_semantics() {
    use gcore::runtime::hlo::Program;

    // f32 → u32: jax(np.uint32(...)) gives -1.5→0, NaN→0, 5e9→u32::MAX
    let text = r#"ENTRY %m (x: f32[6]) -> (u32[6]) {
  %x = f32[6] parameter(0)
  %u = u32[6] convert(f32[6] %x)
  ROOT %t = (u32[6]) tuple(u32[6] %u)
}
"#;
    let p = Program::parse(text).unwrap();
    let x = Tensor::f32(
        vec![6],
        vec![-1.5, f32::NAN, 5e9, 0.0, 42.9, -0.0],
    );
    let out = p.evaluate(&[x]).unwrap();
    let want: [u32; 6] = [0, 0, 4294967295, 0, 42, 0];
    let got: Vec<u32> = out[0]
        .raw_bytes()
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    assert_eq!(got, want, "f32->u32 must saturate like jax (numpy cast)");

    // f32 → s32: -1.5 truncates toward zero to -1, NaN→0, ±overflow
    // saturates at i32::MIN/MAX
    let text = r#"ENTRY %m (x: f32[7]) -> (s32[7]) {
  %x = f32[7] parameter(0)
  %s = s32[7] convert(f32[7] %x)
  ROOT %t = (s32[7]) tuple(s32[7] %s)
}
"#;
    let p = Program::parse(text).unwrap();
    let x = Tensor::f32(
        vec![7],
        vec![-1.5, f32::NAN, 5e9, -5e9, 1.9, -0.0, f32::NEG_INFINITY],
    );
    let out = p.evaluate(&[x]).unwrap();
    let want = [-1, 0, i32::MAX, i32::MIN, 1, 0, i32::MIN];
    assert_eq!(out[0].as_i32().unwrap(), &want, "f32->s32 jax semantics");
}

/// Re-running an artifact must be bitwise deterministic — the property the
/// SPMD launch and the greedy-eval tests rely on.
#[test]
fn interpreter_is_bitwise_deterministic() {
    let engine = Engine::try_load("synthetic").expect("fixture set missing");
    for name in ["fwd_logits", "policy_grad", "init_policy"] {
        let inputs = golden_inputs(&engine, name);
        let a = engine.run(name, &inputs).unwrap();
        let b = engine.run(name, &inputs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.raw_bytes(), y.raw_bytes(), "{name} not deterministic");
        }
    }
}

/// Differential interp == PJRT on the fixture artifacts.  Compiled only
/// with the `pjrt` feature — with the interpreter always available there
/// is no runtime skip left, and without the feature the comparison target
/// itself does not exist.
#[cfg(feature = "pjrt")]
#[test]
fn interp_matches_pjrt_on_fixture_artifacts() {
    use gcore::runtime::engine::BackendKind;
    let dir = fixture_dir("artifacts/synthetic");
    let interp = Engine::from_dir_with_backend(&dir, BackendKind::Interp).unwrap();
    let pjrt = Engine::from_dir_with_backend(&dir, BackendKind::Pjrt).unwrap();
    let names: Vec<String> = interp.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let inputs = golden_inputs(&interp, &name);
        let a = interp.run(&name, &inputs).unwrap();
        let b = pjrt.run(&name, &inputs).unwrap();
        assert_eq!(a.len(), b.len(), "{name}");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_close(&name, i, x, y, 5e-5, 5e-4);
        }
    }
}
