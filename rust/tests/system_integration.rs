//! System-level integration: checkpoint/restore across world sizes, RPC
//! over TCP under fault injection, config round-trips, and the failure
//! paths the paper's fail-fast philosophy (§4.2) mandates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use gcore::checkpoint::{CheckpointManager, CheckpointMeta, ShardState};
use gcore::config::RunConfig;
use gcore::coordinator::collective::Collective;
use gcore::coordinator::controller::Controller;
use gcore::reward::Rewarder;
use gcore::rpc::client::{RetryPolicy, RpcClient};
use gcore::rpc::server::{RpcServer, Service};
use gcore::rpc::transport::{FlakyTransport, TcpRpcHost, TcpTransport, Transport};
use gcore::rpc::wire::Request;
use gcore::runtime::{init_policy, Engine};
use gcore::storage::dataloader::LoaderState;
use gcore::storage::kv::KvStore;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join("gcore_sys_tests")
        .join(format!("{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Loads the tiny artifact set.  PANICS when the set is missing: the
/// fixture set is checked in (rust/tests/fixtures/artifacts/tiny) and the
/// interpreter backend is always available, so there is no legitimate
/// skip reason left — the tier fails loudly if either regresses.
fn try_engine() -> Arc<Engine> {
    match Engine::try_load("tiny") {
        Some(e) => Arc::new(e),
        None => panic!(
            "tiny artifact set not found — regenerate the checked-in \
             fixtures with `python -m compile.fixturegen`"
        ),
    }
}

#[test]
fn checkpoint_resume_continues_training() {
    // Train 2 steps, checkpoint, restore into a FRESH controller, verify
    // the params match bit-exactly and training can continue.
    let engine = try_engine();
    let cfg = RunConfig { steps: 2, sft_steps: 2, ..RunConfig::default() };
    let policy = init_policy(&engine, 1).unwrap();
    let mut c = Controller::new(
        0,
        engine.clone(),
        Collective::new(1),
        cfg.clone(),
        policy,
        Rewarder::ground_truth(),
    )
    .unwrap();
    for _ in 0..2 {
        c.sft_step().unwrap();
    }
    c.freeze_reference();
    c.rlhf_step(0).unwrap();

    let dir = tmpdir("resume");
    let mgr = CheckpointManager::new(&dir);
    let meta = CheckpointMeta {
        step: 1,
        world_size: 1,
        loader: LoaderState { seed: cfg.seed, epoch: 0, cursor: 4 },
    };
    let shard = ShardState {
        rank: 0,
        params: vec![
            ("policy".into(), c.state.params.clone()),
            ("adam_m".into(), c.state.m.clone()),
            ("adam_v".into(), c.state.v.clone()),
            ("reference".into(), c.ref_params.clone()),
        ],
        rng_seed: cfg.seed,
        opt_step: c.state.step,
        controller_rng: Some(c.rng.state()),
        taskgen_rng: Some(c.taskgen.rng_state()),
    };
    mgr.save_shard(1, &meta, &shard).unwrap();

    // fresh controller from the checkpoint
    let loaded = mgr.load_shard(1, 0).unwrap();
    let restored_policy = loaded.params[0].1.clone();
    assert_eq!(restored_policy, c.state.params);
    let mut c2 = Controller::new(
        0,
        engine.clone(),
        Collective::new(1),
        cfg,
        restored_policy,
        Rewarder::ground_truth(),
    )
    .unwrap();
    c2.state.m = loaded.params[1].1.clone();
    c2.state.v = loaded.params[2].1.clone();
    c2.state.step = meta.step;
    c2.ref_params = loaded.params[3].1.clone();
    // resumed training step must succeed and stay finite
    let stats = c2.rlhf_step(1).unwrap();
    assert!(stats.loss.is_finite());
}

#[test]
fn tcp_rpc_exactly_once_under_faults() {
    // The E8 scenario over the REAL TCP transport: response loss + client
    // retries; the server must execute each logical call exactly once.
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let server = Arc::new(RpcServer::new(move |_: &str, p: &[u8]| {
        c2.fetch_add(1, Ordering::SeqCst);
        Ok(p.to_vec())
    }));
    let host = TcpRpcHost::spawn(server.clone()).unwrap();
    let flaky = FlakyTransport::new(TcpTransport::connect(host.addr), 42)
        .with_probs(0.15, 0.25, 0.1);
    let client = RpcClient::new(flaky)
        .with_retry(RetryPolicy::exponential(64, Duration::from_micros(50)));
    let calls = 60u64;
    for i in 0..calls {
        let out = client.call("work", i.to_le_bytes().to_vec()).unwrap();
        assert_eq!(out, i.to_le_bytes().to_vec());
    }
    assert_eq!(count.load(Ordering::SeqCst), calls, "exactly-once violated");
    assert_eq!(server.stats().cached_now, 0, "cleanups must drain the cache");
}

#[test]
fn tcp_rpc_many_concurrent_clients() {
    let server = Arc::new(RpcServer::new(|_: &str, p: &[u8]| Ok(p.to_vec())));
    let host = TcpRpcHost::spawn(server.clone()).unwrap();
    let addr = host.addr;
    let threads: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let client = RpcClient::new(TcpTransport::connect(addr));
                for i in 0..50u64 {
                    let v = (t * 1000 + i).to_le_bytes().to_vec();
                    assert_eq!(client.call("echo", v.clone()).unwrap(), v);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(server.stats().executed, 400);
}

#[test]
fn rpc_server_error_is_fail_fast_signal() {
    // paper §4.2: unexpected result → terminate everything.  The client
    // surfaces server-side errors as hard errors without retry.
    struct Exploding;
    impl Service for Exploding {
        fn handle(&self, _m: &str, _p: &[u8]) -> anyhow::Result<Vec<u8>> {
            anyhow::bail!("CUDA error: device-side assert")
        }
    }
    let server = Arc::new(RpcServer::new(Exploding));
    let host = TcpRpcHost::spawn(server.clone()).unwrap();
    let client = RpcClient::new(TcpTransport::connect(host.addr));
    let err = client.call("train", vec![]).unwrap_err().to_string();
    assert!(err.contains("device-side assert"), "{err}");
    assert_eq!(server.stats().executed, 1, "no retry on server error");
}

#[test]
fn kv_store_holds_multimodal_payloads() {
    // §4.6: images in the KV store instead of many files
    use gcore::data::payload::PayloadSpec;
    use gcore::util::rng::Rng;
    let dir = tmpdir("kv_payload");
    let mut kv = KvStore::open(dir.join("train_data.kv")).unwrap();
    let spec = PayloadSpec::paper_2k().scaled(64);
    let mut rng = Rng::new(1);
    for sid in 0..8u64 {
        let p = spec.generate(sid, &mut rng);
        for (i, img) in p.images.iter().enumerate() {
            kv.put(&format!("sample/{sid}/img/{i}"), img).unwrap();
        }
    }
    assert_eq!(kv.len(), 8 * spec.images_per_sample);
    assert_eq!(kv.scan_prefix("sample/3/").len(), spec.images_per_sample);
    let img = kv.get("sample/0/img/0").unwrap().unwrap();
    assert_eq!(img.len(), spec.bytes_per_image());
}

#[test]
fn config_file_roundtrip_through_launcher_path() {
    let dir = tmpdir("config");
    let path = dir.join("run.json");
    std::fs::write(
        &path,
        r#"{"artifacts":"tiny","world":1,"steps":1,"sft_steps":1,
            "reward":"ground_truth","tasks":["copy"]}"#,
    )
    .unwrap();
    let cfg = RunConfig::load(&path).unwrap();
    assert_eq!(cfg.steps, 1);
    // the preset configs in configs/ must all parse
    for preset in [
        "configs/tiny_groundtruth.json",
        "configs/quickstart_grpo.json",
        "configs/dapo.json",
        "configs/genrm.json",
        "configs/e2e.json",
    ] {
        // tests may run from the crate root
        if std::path::Path::new(preset).exists() {
            RunConfig::load(preset)
                .unwrap_or_else(|e| panic!("{preset} failed to parse: {e:#}"));
        }
    }
}

#[test]
fn controller_rejects_bad_group_size() {
    let engine = try_engine();
    let cfg = RunConfig { group_size: 3, ..RunConfig::default() }; // 4 % 3 != 0
    let policy = init_policy(&engine, 1).unwrap();
    let err = Controller::new(
        0,
        engine,
        Collective::new(1),
        cfg,
        policy,
        Rewarder::ground_truth(),
    )
    .err()
    .expect("must reject");
    assert!(err.to_string().contains("group_size"));
}

#[test]
fn tcp_collective_launch_bitwise_matches_inproc_threads() {
    // The acceptance bar for the RPC-backed collective (§3.1 + §4.2): four
    // controllers coordinating over the TCP rendezvous collective must
    // produce a per-step loss trajectory BIT-IDENTICAL to the in-proc
    // thread launch of the same config/seed — the transport may not perturb
    // training by a single ULP.
    let _e = try_engine();
    let cfg = RunConfig {
        artifacts: "tiny".into(),
        world: 4,
        steps: 2,
        sft_steps: 2,
        group_size: 4,
        seed: 23,
        ..RunConfig::default()
    };
    let inproc = gcore::launch::run_training(&cfg).unwrap();
    let tcp = gcore::launch::run_training_tcp(&cfg).unwrap();

    assert_eq!(inproc.steps.len(), tcp.steps.len());
    for (a, b) in inproc.steps.iter().zip(&tcp.steps) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {} loss diverged: {} vs {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.kl.to_bits(), b.kl.to_bits(), "step {} kl", a.step);
        assert_eq!(
            a.mean_reward.to_bits(),
            b.mean_reward.to_bits(),
            "step {} reward",
            a.step
        );
        assert_eq!(
            a.accuracy.to_bits(),
            b.accuracy.to_bits(),
            "step {} accuracy",
            a.step
        );
        assert_eq!(
            a.mean_gen_len.to_bits(),
            b.mean_gen_len.to_bits(),
            "step {} gen_len",
            a.step
        );
    }
    let sft_a: Vec<u32> = inproc.sft_losses.iter().map(|l| l.to_bits()).collect();
    let sft_b: Vec<u32> = tcp.sft_losses.iter().map(|l| l.to_bits()).collect();
    assert_eq!(sft_a, sft_b, "SFT warm-start trajectory diverged");
    assert_eq!(
        inproc.eval_after.to_bits(),
        tcp.eval_after.to_bits(),
        "final evaluation diverged"
    );
}

#[test]
fn ring_collective_launch_bitwise_matches_inproc_threads() {
    // The acceptance bar for the ring backend: four controllers streaming
    // chunked frames around a loopback-TCP ring must produce a per-step
    // loss trajectory BIT-IDENTICAL to the in-proc thread launch of the
    // same config/seed — rank-order chunked accumulation may not perturb
    // training by a single ULP.
    let _e = try_engine();
    let cfg = RunConfig {
        artifacts: "tiny".into(),
        world: 4,
        steps: 2,
        sft_steps: 2,
        group_size: 4,
        seed: 23,
        ring_chunk_bytes: 64, // force multi-chunk gradient streams
        ..RunConfig::default()
    };
    let inproc = gcore::launch::run_training(&cfg).unwrap();
    let ring = gcore::launch::run_training_ring(&cfg).unwrap();

    assert_eq!(inproc.steps.len(), ring.steps.len());
    for (a, b) in inproc.steps.iter().zip(&ring.steps) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {} loss diverged: {} vs {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.kl.to_bits(), b.kl.to_bits(), "step {} kl", a.step);
        assert_eq!(
            a.mean_reward.to_bits(),
            b.mean_reward.to_bits(),
            "step {} reward",
            a.step
        );
        assert_eq!(
            a.accuracy.to_bits(),
            b.accuracy.to_bits(),
            "step {} accuracy",
            a.step
        );
    }
    let sft_a: Vec<u32> = inproc.sft_losses.iter().map(|l| l.to_bits()).collect();
    let sft_b: Vec<u32> = ring.sft_losses.iter().map(|l| l.to_bits()).collect();
    assert_eq!(sft_a, sft_b, "SFT warm-start trajectory diverged");
    assert_eq!(
        inproc.eval_after.to_bits(),
        ring.eval_after.to_bits(),
        "final evaluation diverged"
    );
}

#[test]
fn tombstone_eviction_under_tcp_load_is_safe() {
    // A long-job stand-in: many exactly-once calls through a tiny tombstone
    // bound.  Live entries still dedupe, evicted ones re-execute safely,
    // and the set never exceeds its capacity.
    let server = Arc::new(
        RpcServer::new(|_: &str, p: &[u8]| Ok(p.to_vec())).with_tombstone_capacity(8),
    );
    let host = TcpRpcHost::spawn(server.clone()).unwrap();
    let client = RpcClient::new(TcpTransport::connect(host.addr));
    for i in 0..100u64 {
        let v = i.to_le_bytes().to_vec();
        assert_eq!(client.call("echo", v.clone()).unwrap(), v);
    }
    let st = server.stats();
    assert_eq!(st.executed, 100);
    assert!(st.tombstones_now <= 8, "tombstones must stay bounded");
    assert!(st.tombstones_evicted >= 92 - 8, "old tombstones must age out");
    assert_eq!(st.cached_now, 0, "cleanups must still drain the cache");
}

#[test]
fn typed_poison_status_maps_to_worker_exit_code() {
    use gcore::coordinator::rpc_collective::{CollectiveStatus, RendezvousHost, RpcCollective};
    use gcore::rpc::transport::InProcTransport;

    // two ranks run mismatched collectives against one rendezvous: the
    // poison must surface as the TYPED status, and launch must map it to
    // the stable worker exit code train-dist matches on
    let server = RendezvousHost::serve(2);
    let cols: Vec<Arc<gcore::coordinator::collective::Collective>> = (0..2)
        .map(|_| {
            gcore::coordinator::collective::Collective::with_backend(Arc::new(
                RpcCollective::new(InProcTransport::new(server.clone()), 2),
            ))
        })
        .collect();
    let col1 = cols[0].clone();
    let h = std::thread::spawn(move || col1.mean_scalars(0, vec![1.0]));
    let err = cols[1].barrier(1).unwrap_err();
    let _ = h.join().unwrap(); // other rank errors too; outcome checked below

    assert_eq!(
        CollectiveStatus::classify_error(&err),
        Some(CollectiveStatus::Poisoned)
    );
    assert_eq!(
        gcore::launch::worker_exit_code(&err),
        CollectiveStatus::Poisoned.exit_code()
    );
    // the parent decodes that exit code back into a reason
    assert_eq!(
        gcore::launch::describe_worker_exit(Some(CollectiveStatus::Poisoned.exit_code())),
        Some(CollectiveStatus::Poisoned.describe())
    );
    // non-collective failures stay on the generic exit code, undecoded
    let plain = anyhow::anyhow!("disk full");
    assert_eq!(gcore::launch::worker_exit_code(&plain), 1);
    assert_eq!(gcore::launch::describe_worker_exit(Some(1)), None);
    assert_eq!(gcore::launch::describe_worker_exit(None), None);

    // a dead peer times out with the typed status as well
    let server = RendezvousHost::serve(2);
    let lonely = gcore::coordinator::collective::Collective::with_backend(Arc::new(
        RpcCollective::new(InProcTransport::new(server), 2)
            .with_round_timeout(Duration::from_millis(20)),
    ));
    let err = lonely.barrier(0).unwrap_err();
    assert_eq!(
        CollectiveStatus::classify_error(&err),
        Some(CollectiveStatus::RoundTimeout)
    );
    assert_eq!(
        gcore::launch::worker_exit_code(&err),
        CollectiveStatus::RoundTimeout.exit_code()
    );
}

#[test]
fn flaky_transport_duplicates_do_not_reexecute() {
    // duplicates delivered straight to the server (no client involved)
    let count = Arc::new(AtomicU64::new(0));
    let c2 = count.clone();
    let server = Arc::new(RpcServer::new(move |_: &str, _: &[u8]| {
        c2.fetch_add(1, Ordering::SeqCst);
        Ok(vec![])
    }));
    let t = gcore::rpc::transport::InProcTransport::new(server.clone());
    let req = Request { id: 77, method: "m".into(), payload: vec![] };
    for _ in 0..5 {
        t.deliver(&req).unwrap();
    }
    assert_eq!(count.load(Ordering::SeqCst), 1);
    assert_eq!(server.stats().duplicates_served, 4);
}
