"""Model / artifact-set configuration for the G-Core reproduction.

A ``ModelConfig`` fully determines the shapes of every AOT artifact emitted
by ``aot.py``: the transformer dimensions, the rollout batch size, the
maximum sequence length, and whether the attention hot-spot is lowered
through the Pallas kernel (L1) or the pure-jnp reference path (same math,
verified equal by the pytest suite).

The Rust coordinator (L3) never sees this file — it reads the JSON manifest
emitted next to the HLO artifacts.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Shape/config record for one artifact set."""

    name: str = "tiny"
    # -- transformer dims --------------------------------------------------
    vocab: int = 256          # byte-level vocabulary
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 256
    max_seq: int = 64         # S: full sequence length (prompt + response)
    prompt_len: int = 16      # P: fixed prompt length (left-padded by L3)
    # -- batch shape baked into artifacts ----------------------------------
    batch: int = 4            # B: rollout / train micro-batch
    # -- kernel selection ---------------------------------------------------
    use_pallas: bool = True   # lower attention through the L1 Pallas kernel
    block_q: int = 32         # Pallas q-tile
    block_k: int = 32         # Pallas kv-tile
    # -- optimiser constants baked into adam_apply --------------------------
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1.0e-8
    weight_decay: float = 0.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def gen_len(self) -> int:
        """Maximum number of generated tokens."""
        return self.max_seq - self.prompt_len

    def param_count(self) -> int:
        """Exact parameter count of the policy (LM-head) model."""
        d, v, s, f, l = self.d_model, self.vocab, self.max_seq, self.d_ff, self.n_layers
        per_block = (
            2 * d          # ln1 g,b
            + 4 * d * d    # wq wk wv wo
            + 2 * d        # ln2 g,b
            + d * f + f    # w1 b1
            + f * d + d    # w2 b2
        )
        return v * d + s * d + l * per_block + 2 * d + d * v

    def scalar_param_count(self) -> int:
        """Parameter count of the scalar-head (critic / BT-reward) model."""
        d, v = self.d_model, self.vocab
        return self.param_count() - d * v + d

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ModelConfig":
        fields = {f.name for f in dataclasses.fields(ModelConfig)}
        return ModelConfig(**{k: v for k, v in d.items() if k in fields})


# ---------------------------------------------------------------------------
# Presets. `tiny` is the pytest / cargo-test set; `quickstart` the example
# set; `e2e` the end-to-end training run (EXPERIMENTS.md §E10); `e2e100m`
# is the paper-scale config documented but not built by default (CPU cost).
# ---------------------------------------------------------------------------
PRESETS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", d_model=64, n_layers=2, n_heads=2, d_ff=256,
        max_seq=64, prompt_len=16, batch=4, block_q=16, block_k=16,
    ),
    "quickstart": ModelConfig(
        name="quickstart", d_model=128, n_layers=4, n_heads=4, d_ff=512,
        max_seq=96, prompt_len=24, batch=8, block_q=16, block_k=16,
    ),
    "e2e": ModelConfig(
        name="e2e", d_model=256, n_layers=6, n_heads=8, d_ff=1024,
        max_seq=128, prompt_len=32, batch=16, block_q=32, block_k=32,
    ),
    "e2e100m": ModelConfig(
        name="e2e100m", d_model=768, n_layers=12, n_heads=12, d_ff=3072,
        max_seq=256, prompt_len=64, batch=8, block_q=64, block_k=64,
    ),
}


def load_config(name_or_path: str) -> ModelConfig:
    """Load a preset by name, or a JSON config file by path."""
    if name_or_path in PRESETS:
        return PRESETS[name_or_path]
    with open(name_or_path) as f:
        return ModelConfig.from_json(json.load(f))
