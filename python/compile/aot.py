"""AOT lowering: JAX entry points → HLO **text** artifacts + JSON manifest.

This is the only bridge between the Python build path and the Rust runtime.
Each entry point in `model.py` is jitted, lowered to StableHLO, converted to
an XlaComputation and dumped as HLO *text* — NOT ``.serialize()``: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

The manifest records, for every artifact, the exact flat order / shapes /
dtypes of HLO parameters and tuple outputs (jax flattens arguments in
pytree order, which for dicts is sorted-key order — deterministic), plus
the policy/scalar parameter trees so the Rust side can checkpoint, shard
and all-reduce parameter and gradient lists without ever reconstructing a
pytree.

Usage:  python -m compile.aot --config tiny --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import ModelConfig, PRESETS, load_config
from .kernels.attention import (
    flash_attention,
    vmem_footprint_bytes,
    mxu_utilization_estimate,
    attention_flops,
)

_DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
    jnp.dtype("bfloat16"): "bf16",
}


def _spec(x) -> dict:
    return {"shape": list(x.shape), "dtype": _DTYPE_NAMES[jnp.dtype(x.dtype)]}


def _flatten_with_names(tree, prefix: str) -> list[tuple[str, object]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = prefix + "".join(
            f"/{p.key}" if hasattr(p, "key") else f"/{p.idx}" for p in path
        )
        out.append((name, leaf))
    return out


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entry_points(cfg: ModelConfig) -> dict:
    """name -> (fn, example_args: tuple of pytrees, arg_names)."""
    B, S, P, V = cfg.batch, cfg.max_seq, cfg.prompt_len, cfg.vocab
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.d_head

    policy = jax.eval_shape(
        lambda s: model.init_params(cfg, s, scalar_head=False),
        _sds((), jnp.uint32),
    )
    scalar = jax.eval_shape(
        lambda s: model.init_params(cfg, s, scalar_head=True),
        _sds((), jnp.uint32),
    )
    cache = _sds((L, B, H, S, Dh))
    tok_bs = _sds((B, S), jnp.int32)
    f_bs = _sds((B, S))
    f0 = _sds(())
    i_b = _sds((B,), jnp.int32)

    eps = {}

    def ep(name, fn, args, arg_names):
        eps[name] = (fn, args, arg_names)

    ep("init_policy",
       lambda seed: model.init_params(cfg, seed, scalar_head=False),
       (_sds((), jnp.uint32),), ["seed"])
    ep("init_scalar",
       lambda seed: model.init_params(cfg, seed, scalar_head=True),
       (_sds((), jnp.uint32),), ["seed"])
    ep("fwd_logits",
       lambda p, t: model.logits_fn(cfg, p, t),
       (policy, tok_bs), ["params", "tokens"])
    ep("logprob",
       lambda p, t: model.logprob_fn(cfg, p, t),
       (policy, tok_bs), ["params", "tokens"])
    ep("prefill",
       lambda p, t: model.prefill(cfg, p, t),
       (policy, _sds((B, P), jnp.int32)), ["params", "tokens"])
    ep("decode_step",
       lambda p, ck, cv, tok, pos: model.decode_step(cfg, p, ck, cv, tok, pos),
       (policy, cache, cache, i_b, _sds((), jnp.int32)),
       ["params", "cache_k", "cache_v", "token", "pos"])
    ep("generate_rollout",
       lambda p, pr, seed, temp: model.generate_rollout(cfg, p, pr, seed, temp),
       (policy, _sds((B, P), jnp.int32), _sds((), jnp.uint32), _sds(())),
       ["params", "prompts", "seed", "temperature"])
    ep("value_score",
       lambda p, t: model.values_fn(cfg, p, t),
       (scalar, tok_bs), ["params", "tokens"])
    ep("reward_score",
       lambda p, t, i: model.reward_score(cfg, p, t, i),
       (scalar, tok_bs, i_b), ["params", "tokens", "last_idx"])
    ep("policy_grad",
       lambda p, t, m, a, ol, rl, ce, kc, ec: model.policy_grad(
           cfg, p, t, m, a, ol, rl, ce, kc, ec),
       (policy, tok_bs, f_bs, f_bs, f_bs, f_bs, f0, f0, f0),
       ["params", "tokens", "mask", "adv", "old_logp", "ref_logp",
        "clip_eps", "kl_coef", "ent_coef"])
    ep("sft_grad",
       lambda p, t, m: model.sft_grad(cfg, p, t, m),
       (policy, tok_bs, f_bs), ["params", "tokens", "mask"])
    ep("critic_grad",
       lambda p, t, m, r: model.critic_grad(cfg, p, t, m, r),
       (scalar, tok_bs, f_bs, f_bs), ["params", "tokens", "mask", "returns"])
    ep("bt_grad",
       lambda p, c, r, ci, ri: model.bt_grad(cfg, p, c, r, ci, ri),
       (scalar, tok_bs, tok_bs, i_b, i_b),
       ["params", "chosen", "rejected", "chosen_idx", "rejected_idx"])
    ep("adam_policy",
       lambda p, m, v, g, st, lr: model.adam_apply(cfg, p, m, v, g, st, lr),
       (policy, policy, policy, policy, f0, f0),
       ["params", "m", "v", "grads", "step", "lr"])
    ep("adam_scalar",
       lambda p, m, v, g, st, lr: model.adam_apply(cfg, p, m, v, g, st, lr),
       (scalar, scalar, scalar, scalar, f0, f0),
       ["params", "m", "v", "grads", "step", "lr"])
    ep("train_step",
       lambda p, m, v, t, mk, a, ol, rl, st, lr, ce, kc, ec: model.train_step(
           cfg, p, m, v, t, mk, a, ol, rl, st, lr, ce, kc, ec),
       (policy, policy, policy, tok_bs, f_bs, f_bs, f_bs, f_bs,
        f0, f0, f0, f0, f0),
       ["params", "m", "v", "tokens", "mask", "adv", "old_logp", "ref_logp",
        "step", "lr", "clip_eps", "kl_coef", "ent_coef"])
    ep("attn_micro",
       lambda q, k, v: flash_attention(
           q, k, v, causal=True, block_q=cfg.block_q, block_k=cfg.block_k),
       (_sds((B, H, S, Dh)), _sds((B, H, S, Dh)), _sds((B, H, S, Dh))),
       ["q", "k", "v"])
    return eps


def lower_all(cfg: ModelConfig, out_dir: str, *, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    eps = build_entry_points(cfg)
    artifacts = {}
    for name, (fn, args, arg_names) in eps.items():
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # flat input/output specs in HLO parameter order
        inputs = []
        for arg_name, arg in zip(arg_names, args):
            for leaf_name, leaf in _flatten_with_names(arg, arg_name):
                inputs.append({"name": leaf_name, **_spec(leaf)})
        out_shape = jax.eval_shape(fn, *args)
        outputs = [
            {"name": n, **_spec(leaf)}
            for n, leaf in _flatten_with_names(out_shape, "out")
        ]
        artifacts[name] = {
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
            "hlo_bytes": len(text),
        }
        if verbose:
            print(
                f"  {name:<14} {len(inputs):>4} in / {len(outputs):>3} out "
                f"{len(text) / 1e6:6.2f} MB HLO  ({time.time() - t0:.1f}s)"
            )
    return artifacts


def build_manifest(cfg: ModelConfig, artifacts: dict) -> dict:
    policy = jax.eval_shape(
        lambda s: model.init_params(cfg, s, scalar_head=False),
        _sds((), jnp.uint32),
    )
    scalar = jax.eval_shape(
        lambda s: model.init_params(cfg, s, scalar_head=True),
        _sds((), jnp.uint32),
    )
    policy_tree = [
        {"path": n, **_spec(leaf)} for n, leaf in _flatten_with_names(policy, "p")
    ]
    scalar_tree = [
        {"path": n, **_spec(leaf)} for n, leaf in _flatten_with_names(scalar, "p")
    ]
    S, Dh = cfg.max_seq, cfg.d_head
    return {
        "format_version": 1,
        "config": cfg.to_json(),
        "param_count": cfg.param_count(),
        "scalar_param_count": cfg.scalar_param_count(),
        "policy_tree": policy_tree,
        "scalar_tree": scalar_tree,
        "artifacts": artifacts,
        # parameters baked into the fused generate_rollout artifact; the
        # Rust generation gate compares SamplerConfig against this block
        # and errors loudly on a mismatch
        "sampler": {
            "top_k": model.ROLLOUT_TOP_K,
            "stop_at_eos": model.ROLLOUT_STOP_AT_EOS,
        },
        "perf_estimates": {
            "attn_vmem_bytes_per_grid_step": vmem_footprint_bytes(
                cfg.block_q, cfg.block_k, Dh
            ),
            "attn_mxu_utilization": mxu_utilization_estimate(
                S, Dh, cfg.block_q, cfg.block_k, causal=True
            ),
            "attn_flops_causal": attention_flops(
                cfg.batch, cfg.n_heads, S, Dh, causal=True
            ),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny",
                    help=f"preset ({', '.join(PRESETS)}) or JSON path")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower attention through the jnp path instead of "
                         "the Pallas kernel (faster CPU execution; same math)")
    args = ap.parse_args()

    cfg = load_config(args.config)
    if args.no_pallas:
        import dataclasses
        cfg = dataclasses.replace(cfg, use_pallas=False)
    out_dir = os.path.join(args.out_dir, cfg.name)
    print(f"[aot] lowering config '{cfg.name}' "
          f"({cfg.param_count() / 1e6:.2f}M params, pallas={cfg.use_pallas}) "
          f"-> {out_dir}")
    t0 = time.time()
    artifacts = lower_all(cfg, out_dir)
    manifest = build_manifest(cfg, artifacts)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(artifacts)} artifacts + manifest in "
          f"{time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
