"""HLO-text parser + numpy (float32) evaluator.

A Python mirror of `rust/src/runtime/hlo/{parser,eval}.rs` used at
fixture-generation time: the emitted artifact text is round-tripped
through *this* parser/evaluator and differentially compared against the
real jax model, so the committed text is known-good before the Rust
interpreter ever sees it.  Keep the two in sync when extending the op set.
"""

from __future__ import annotations

import re

import numpy as np

_DTYPES = {"f32": np.float32, "s32": np.int32, "u32": np.uint32, "pred": np.bool_}


class Instr:
    __slots__ = ("name", "dtype", "dims", "opcode", "operands", "attrs")

    def __init__(self, name, dtype, dims, opcode, operands, attrs):
        self.name = name
        self.dtype = dtype
        self.dims = dims
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs = []
        self.params = []
        self.root = None


def _split_top(s):
    out, depth, start = [], 0, 0
    for i, c in enumerate(s):
        if c in "{([":
            depth += 1
        elif c in "})]":
            depth -= 1
        elif c == "," and depth == 0:
            out.append(s[start:i].strip())
            start = i + 1
    tail = s[start:].strip()
    if tail:
        out.append(tail)
    return out


def _matching_paren(s, open_idx):
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] in "{([":
            depth += 1
        elif s[i] in "})]":
            depth -= 1
            if depth == 0:
                return i
    raise ValueError(f"unbalanced parens in {s!r}")


_SHAPE_RE = re.compile(r"^\s*(f32|s32|u32|pred)\[([0-9,]*)\]")


def _parse_shape(s):
    m = _SHAPE_RE.match(s)
    if not m:
        raise ValueError(f"bad shape at {s[:40]!r}")
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    rest = s[m.end():]
    if rest.startswith("{"):  # layout suffix
        close = _matching_paren(rest, 0)
        rest = rest[close + 1:]
    return m.group(1), dims, rest


def _int_list(v):
    inner = v.strip().strip("{}").strip()
    return [int(x) for x in inner.split(",")] if inner else []


class Module:
    def __init__(self, text):
        self.computations = {}
        self.entry = None
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith("HloModule") or line.startswith("//"):
                continue
            if line == "}":
                cur = None
                continue
            if line.endswith("{") and "->" in line:
                name = line.lstrip("ENTRY").strip().split("(")[0].strip().lstrip("%")
                cur = Computation(name)
                self.computations[name] = cur
                if raw.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            assert cur is not None, f"instruction outside computation: {line}"
            self._parse_instr(cur, line)
        if self.entry is None:
            if len(self.computations) != 1:
                raise ValueError("no ENTRY computation")
            self.entry = next(iter(self.computations.values()))

    def _parse_instr(self, comp, line):
        is_root = line.startswith("ROOT ")
        if is_root:
            line = line[5:]
        name, rhs = line.split("=", 1)
        name = name.strip().lstrip("%")
        rhs = rhs.strip()
        if rhs.startswith("("):  # tuple shape
            close = _matching_paren(rhs, 0)
            dtype, dims = None, None
            rhs = rhs[close + 1:].strip()
        else:
            dtype, dims, rhs = _parse_shape(rhs)
            rhs = rhs.strip()
        open_idx = rhs.index("(")
        opcode = rhs[:open_idx].strip()
        close = _matching_paren(rhs, open_idx)
        operand_str = rhs[open_idx + 1:close]
        attr_str = rhs[close + 1:].lstrip(",").strip()

        by_name = {ins.name: k for k, ins in enumerate(comp.instrs)}
        operands = []
        attrs = {}
        if opcode == "parameter":
            attrs["index"] = int(operand_str)
        elif opcode == "constant":
            flat = operand_str.replace("{", "").replace("}", "")
            toks = [t.strip() for t in flat.split(",") if t.strip()]

            def lit(t):
                if dtype == "f32":
                    return np.float32(float(t))
                if dtype == "pred":
                    return t in ("true", "1")
                return int(t)

            attrs["literal"] = np.array([lit(t) for t in toks],
                                        dtype=_DTYPES[dtype]).reshape(dims)
        else:
            for frag in _split_top(operand_str):
                opname = [t for t in frag.split() if t.startswith("%")][-1]
                operands.append(by_name[opname.lstrip("%")])
        for attr in _split_top(attr_str):
            if not attr or "=" not in attr:
                continue
            k, v = attr.split("=", 1)
            k, v = k.strip(), v.strip()
            if k in ("dimensions", "dynamic_slice_sizes", "lhs_batch_dims",
                     "rhs_batch_dims", "lhs_contracting_dims",
                     "rhs_contracting_dims", "offset_dims",
                     "collapsed_slice_dims", "start_index_map", "slice_sizes",
                     "update_window_dims", "inserted_window_dims",
                     "scatter_dims_to_operand_dims"):
                attrs[k] = _int_list(v)
            elif k in ("iota_dimension", "index_vector_dim", "index"):
                attrs[k] = int(v)
            elif k == "slice":
                parts = _split_top(v.strip().strip("{}"))
                attrs["slice"] = [tuple(int(x) for x in p.strip("[] ").split(":"))
                                  for p in parts]
            elif k == "padding":
                attrs["padding"] = [tuple(int(x) for x in p.split("_"))
                                    for p in v.split("x")]
            elif k == "direction":
                attrs["direction"] = v
            elif k in ("to_apply", "condition", "body"):
                attrs[k] = v.lstrip("%")
        ins = Instr(name, dtype, dims, opcode, operands, attrs)
        idx = len(comp.instrs)
        comp.instrs.append(ins)
        if opcode == "parameter":
            comp.params.append((attrs["index"], idx))
        if is_root:
            comp.root = idx


_CMP = {
    "EQ": np.equal, "NE": np.not_equal, "LT": np.less, "LE": np.less_equal,
    "GT": np.greater, "GE": np.greater_equal,
}

_U32 = np.uint32


def evaluate(module: Module, inputs):
    """Evaluate the ENTRY computation; returns list of np arrays."""
    comp = module.entry
    assert len(comp.params) == len(inputs), (len(comp.params), len(inputs))
    err = np.seterr(all="ignore")  # inf/0*inf semantics mirror f32 hardware
    try:
        result = _run_comp(module, comp, list(inputs))
    finally:
        np.seterr(**err)
    assert isinstance(result, list), "entry root must be a tuple"
    return result


def _run_comp(module, comp, inputs):
    """Run one computation with flat positional inputs.

    Returns the root value: a list for a tuple root, an ndarray otherwise.
    Shared by the ENTRY path and `while` cond/body recursion.
    """
    params = {idx: inputs[pnum] for pnum, idx in sorted(comp.params)}
    vals = [None] * len(comp.instrs)
    for i, ins in enumerate(comp.instrs):
        if ins.opcode == "tuple":
            vals[i] = [vals[o] for o in ins.operands]
            continue
        vals[i] = _exec(module, ins, [vals[o] for o in ins.operands],
                        params.get(i))
        if ins.dims is not None and isinstance(vals[i], np.ndarray):
            assert tuple(vals[i].shape) == ins.dims, (
                ins.name, ins.opcode, vals[i].shape, ins.dims)
    return vals[comp.root]


def _hash_u32(z):
    """lowbias32-style mixer over uint32; mirrors `modelgen.M.hash_u32`."""
    z = np.asarray(z, dtype=_U32)
    for mul, shift in ((0xED5AD4BB, 17), (0xAC4C1B51, 11), (0x31848BAB, 15)):
        z = (z ^ (z >> _U32(shift))) * _U32(mul)
    return z ^ (z >> _U32(14))


def _f32(x):
    return np.asarray(x, dtype=np.float32)


def _exec(module, ins, args, param_val):
    op = ins.opcode
    if op == "parameter":
        a = np.asarray(param_val, dtype=_DTYPES[ins.dtype]).reshape(ins.dims)
        return a
    if op == "constant":
        return ins.attrs["literal"]
    a = args[0] if args else None
    if op == "add":
        return a + args[1] if a.dtype != _U32 else (a + args[1]).astype(_U32)
    if op == "subtract":
        return a - args[1]
    if op == "multiply":
        return a * args[1]
    if op == "divide":
        return _f32(a / args[1]) if a.dtype == np.float32 else a // args[1]
    if op == "maximum":
        return np.maximum(a, args[1])
    if op == "minimum":
        return np.minimum(a, args[1])
    if op == "power":
        return _f32(np.power(a, args[1]))
    if op == "and":
        return a & args[1]
    if op == "or":
        return a | args[1]
    if op == "xor":
        return a ^ args[1]
    if op == "shift-left":
        return (a.astype(np.uint64) << args[1].astype(np.uint64)).astype(_U32)
    if op == "shift-right-logical":
        return (a >> args[1]).astype(a.dtype)
    if op == "negate":
        return -a
    if op == "abs":
        return np.abs(a)
    if op == "exponential":
        return _f32(np.exp(a))
    if op == "log":
        return _f32(np.log(a))
    if op == "tanh":
        return _f32(np.tanh(a))
    if op == "rsqrt":
        return _f32(1.0 / np.sqrt(a, dtype=np.float32))
    if op == "sqrt":
        return _f32(np.sqrt(a))
    if op == "sine":
        return _f32(np.sin(a))
    if op == "cosine":
        return _f32(np.cos(a))
    if op == "not":
        return ~a
    if op == "compare":
        return _CMP[ins.attrs["direction"]](a, args[1])
    if op == "select":
        return np.where(args[0], args[1], args[2]).astype(args[1].dtype)
    if op == "convert":
        return a.astype(_DTYPES[ins.dtype])
    if op == "broadcast":
        dims_map = ins.attrs.get("dimensions", [])
        src = a
        # place source axes at dims_map positions, broadcast the rest
        expanded = np.empty(ins.dims, dtype=a.dtype)
        view_shape = [1] * len(ins.dims)
        for i_src, d in enumerate(dims_map):
            view_shape[d] = src.shape[i_src]
        expanded[...] = src.reshape(view_shape)
        return expanded
    if op == "reshape":
        return a.reshape(ins.dims)
    if op == "transpose":
        return np.transpose(a, ins.attrs["dimensions"]).copy()
    if op == "slice":
        spec = ins.attrs["slice"]
        idx = tuple(slice(s[0], s[1], s[2] if len(s) > 2 else 1) for s in spec)
        return a[idx].copy()
    if op == "concatenate":
        return np.concatenate(args, axis=ins.attrs["dimensions"][0])
    if op == "pad":
        pads = [(int(lo), int(hi)) for lo, hi, *_ in ins.attrs["padding"]]
        return np.pad(a, pads, constant_values=args[1])
    if op == "reduce":
        kind = module.computations[ins.attrs["to_apply"]]
        root_op = kind.instrs[kind.root].opcode
        dims = tuple(ins.attrs["dimensions"])
        if root_op == "add":
            return np.sum(a, axis=dims, dtype=a.dtype)
        if root_op == "maximum":
            return np.max(a, axis=dims)
        return np.min(a, axis=dims)
    if op == "dot":
        return _dot(ins, args[0], args[1])
    if op == "iota":
        d = ins.attrs["iota_dimension"]
        out = np.arange(ins.dims[d], dtype=_DTYPES[ins.dtype])
        shape = [1] * len(ins.dims)
        shape[d] = ins.dims[d]
        return np.broadcast_to(out.reshape(shape), ins.dims).copy()
    if op == "dynamic-slice":
        sizes = ins.attrs["dynamic_slice_sizes"]
        starts = [int(x) for x in args[1:]]
        idx = tuple(
            slice(min(max(s, 0), d - z), min(max(s, 0), d - z) + z)
            for s, z, d in zip(starts, sizes, a.shape))
        return a[idx].copy()
    if op == "dynamic-update-slice":
        upd = args[1]
        starts = [int(x) for x in args[2:]]
        out = a.copy()
        idx = tuple(
            slice(min(max(s, 0), d - u), min(max(s, 0), d - u) + u)
            for s, u, d in zip(starts, upd.shape, a.shape))
        out[idx] = upd
        return out
    if op == "gather":
        return _gather(ins, args[0], args[1])
    if op == "while":
        cond = module.computations[ins.attrs["condition"]]
        body = module.computations[ins.attrs["body"]]
        state = list(args)
        while bool(_run_comp(module, cond, state)):
            state = _run_comp(module, body, state)
        return state
    if op == "get-tuple-element":
        return args[0][ins.attrs["index"]]
    if op == "sort":
        comparator = module.computations[ins.attrs["to_apply"]]
        direction = comparator.instrs[comparator.root].attrs["direction"]
        dim = ins.attrs["dimensions"][0]
        srt = np.sort(a, axis=dim)
        if direction in ("GT", "GE"):
            srt = np.flip(srt, axis=dim)
        return srt.copy()
    if op == "rng-bit-generator":
        base = np.asarray(a, dtype=_U32).reshape(())
        n = int(np.prod(ins.dims, dtype=np.int64)) if ins.dims else 1
        ctr = base + np.arange(n, dtype=_U32)
        return _hash_u32(ctr).reshape(ins.dims)
    if op == "rng":
        # deterministic counter-based uniform over [a, b)
        n = int(np.prod(ins.dims, dtype=np.int64)) if ins.dims else 1
        bits = _hash_u32(np.arange(n, dtype=_U32))
        u = ((bits >> _U32(8)).astype(np.float32) + np.float32(0.5)) \
            * np.float32(1.0 / 16777216.0)
        lo = np.float32(args[0])
        hi = np.float32(args[1])
        return (lo + u.reshape(ins.dims) * (hi - lo)).astype(np.float32)
    if op == "scatter":
        return _scatter(module, ins, args[0], args[1], args[2])
    raise ValueError(f"unsupported opcode {op}")


def _dot(ins, lhs, rhs):
    lb, rb = ins.attrs.get("lhs_batch_dims", []), ins.attrs.get("rhs_batch_dims", [])
    lc, rc = ins.attrs["lhs_contracting_dims"], ins.attrs["rhs_contracting_dims"]
    lhs_free = [d for d in range(lhs.ndim) if d not in lb and d not in lc]
    rhs_free = [d for d in range(rhs.ndim) if d not in rb and d not in rc]
    lt = np.transpose(lhs, lb + lhs_free + lc)
    rt = np.transpose(rhs, rb + rc + rhs_free)
    bshape = lt.shape[:len(lb)]
    m = int(np.prod([lhs.shape[d] for d in lhs_free], dtype=np.int64))
    k = int(np.prod([lhs.shape[d] for d in lc], dtype=np.int64))
    n = int(np.prod([rhs.shape[d] for d in rhs_free], dtype=np.int64))
    b = int(np.prod(bshape, dtype=np.int64))
    out = np.matmul(lt.reshape(b, m, k).astype(np.float32),
                    rt.reshape(b, k, n).astype(np.float32))
    out_shape = (tuple(bshape)
                 + tuple(lhs.shape[d] for d in lhs_free)
                 + tuple(rhs.shape[d] for d in rhs_free))
    return out.reshape(out_shape).astype(np.float32)


def _scatter(module, ins, operand, indices, updates):
    g = ins.attrs
    uwd = g["update_window_dims"]
    inserted = g["inserted_window_dims"]
    sdod = g["scatter_dims_to_operand_dims"]
    ivd = g["index_vector_dim"]
    combiner = module.computations[ins.attrs["to_apply"]]
    root_op = combiner.instrs[combiner.root].opcode
    window_operand_dims = [d for d in range(operand.ndim) if d not in inserted]
    update_batch_axes = [a for a in range(updates.ndim) if a not in uwd]
    idx_shape = list(indices.shape)
    out = operand.copy()
    for upd_idx in np.ndindex(*updates.shape):
        batch_idx = [upd_idx[a] for a in update_batch_axes]
        start = [0] * operand.ndim
        for c, od in enumerate(sdod):
            if ivd < len(idx_shape):
                iidx = batch_idx[:ivd] + [c] + batch_idx[ivd:]
            else:
                iidx = batch_idx
            raw = int(indices[tuple(iidx)])
            start[od] = min(max(raw, 0), operand.shape[od] - 1)
        dst = list(start)
        for w_axis, op_dim in zip(uwd, window_operand_dims):
            dst[op_dim] += upd_idx[w_axis]
        dst = tuple(dst)
        if root_op == "add":
            out[dst] = operand.dtype.type(out[dst] + updates[upd_idx])
        elif root_op == "maximum":
            out[dst] = max(out[dst], updates[upd_idx])
        else:
            out[dst] = min(out[dst], updates[upd_idx])
    return out


def _gather(ins, operand, indices):
    g = ins.attrs
    offset_dims = g["offset_dims"]
    collapsed = g["collapsed_slice_dims"]
    start_map = g["start_index_map"]
    ivd = g["index_vector_dim"]
    slice_sizes = g["slice_sizes"]
    out = np.empty(ins.dims, dtype=operand.dtype)
    idx_shape = list(indices.shape)
    batch_shape = [d for i, d in enumerate(idx_shape) if i != ivd] \
        if ivd < len(idx_shape) else idx_shape
    offset_operand_dims = [d for d in range(operand.ndim) if d not in collapsed]
    out_batch_axes = [a for a in range(len(ins.dims)) if a not in offset_dims]
    for out_idx in np.ndindex(*ins.dims):
        batch_idx, slice_idx = [], {}
        for axis, coord in enumerate(out_idx):
            if axis in offset_dims:
                slice_idx[offset_operand_dims[offset_dims.index(axis)]] = coord
            else:
                batch_idx.append(coord)
        full = list(batch_idx)
        start = [0] * operand.ndim
        for c, od in enumerate(start_map):
            if ivd < len(idx_shape):
                iidx = full[:ivd] + [c] + full[ivd:]
            else:
                iidx = full
            raw = int(indices[tuple(iidx)])
            start[od] = min(max(raw, 0), operand.shape[od] - slice_sizes[od])
        src = tuple(start[d] + slice_idx.get(d, 0) for d in range(operand.ndim))
        out[out_idx] = operand[src]
    _ = batch_shape, out_batch_axes
    return out
