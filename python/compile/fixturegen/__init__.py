"""Fixture-artifact generator for the pure-Rust HLO interpreter backend.

This package is the build-time half of `rust/src/runtime/hlo/`: it
constructs the tiny/synthetic model entry points as HLO op graphs
(`hlo_builder`), derives their gradients with reverse-mode autodiff
(`hlo_autodiff`), emits them as HLO *text* in exactly the dialect the Rust
parser accepts (`modelgen`), and validates everything differentially
against the repo's real jax model (`validate`) before the artifacts and
jax-generated goldens are committed under `rust/tests/fixtures/artifacts/`.

CI never runs this code: the artifacts it emits are checked in.  Re-run
with `python -m compile.fixturegen` after changing the model or op set.
"""
