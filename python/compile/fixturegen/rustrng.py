"""Exact ports of `rust/src/util/rng.rs` (xoshiro256++), the byte
tokenizer and `rust/src/data/tasks.rs` — used to simulate the Rust test
suites' exact task streams when validating that the fixture model can meet
their learning thresholds (see `simulate.py`)."""

from __future__ import annotations

M64 = (1 << 64) - 1


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    def __init__(self, seed):
        s = seed & M64
        self.s = []
        for _ in range(4):
            s, z = _splitmix64(s)
            self.s.append(z)

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def fork(self, stream):
        return Rng(self.next_u64() ^ ((stream * 0x9E3779B97F4A7C15) & M64))

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        return int(self.f64() * n) % n

    def bool(self, p):
        return self.f64() < p

    def weighted(self, weights):
        t = self.f64() * sum(weights)
        for i, w in enumerate(weights):
            t -= w
            if t <= 0.0:
                return i
        return len(weights) - 1

    def sample_logits(self, logits, temperature, top_k):
        import numpy as np

        logits = np.asarray(logits, np.float32)
        if temperature <= 0.0:
            return int(np.argmax(logits))
        k = len(logits) if top_k == 0 else min(top_k, len(logits))
        idx = list(np.argsort(-logits, kind="stable")[:k])
        mx = max(float(logits[i]) for i in idx)
        ws = [float(np.exp((float(logits[i]) - mx) / temperature)) for i in idx]
        return int(idx[self.weighted(ws)])


# -- tokenizer (tokenizer.rs) ----------------------------------------------

PAD = 0
EOS = 10  # '\n'


def encode(s):
    return [b for b in s.encode()]


def pad_prompt(s, width):
    toks = encode(s)
    assert len(toks) <= width, s
    return [ord(" ")] * (width - len(toks)) + toks


def extract_response(row, prompt_len):
    gen = row[prompt_len:]
    end = gen.index(EOS) if EOS in gen else len(gen)
    return bytes(t for t in gen[:end] if 0 < t < 256).decode("utf-8", "replace")


def last_token_index(row, prompt_len):
    gen = row[prompt_len:]
    if EOS in gen:
        return prompt_len + gen.index(EOS)
    return len(row) - 1


# -- tasks (tasks.rs) -------------------------------------------------------


class Task:
    def __init__(self, kind, prompt, answer):
        self.kind, self.prompt, self.answer = kind, prompt, answer

    def check(self, response):
        return response.strip() == self.answer

    def prompt_tokens(self, width):
        return pad_prompt(self.prompt, width)

    def demonstration(self, prompt_width, seq):
        row = self.prompt_tokens(prompt_width)
        answer = encode(self.answer + "\n")
        assert len(row) + len(answer) <= seq
        start = len(row)
        row = row + answer
        end = len(row)
        row = row + [PAD] * (seq - len(row))
        m = [0.0] * seq
        for i in range(start, end):
            m[i] = 1.0
        return row, m


class TaskGen:
    def __init__(self, kinds, seed):
        self.kinds = kinds
        self.rng = Rng(seed)

    def sample(self):
        kind = self.kinds[self.rng.below(len(self.kinds))]
        if kind == "add":
            a, b = self.rng.below(10), self.rng.below(10)
            return Task(kind, f"{a}+{b}=", str(a + b))
        if kind == "max":
            a, b = self.rng.below(10), self.rng.below(10)
            return Task(kind, f"max {a} {b}=", str(max(a, b)))
        if kind == "copy":
            s = self._word(3)
            return Task(kind, f"copy {s}=", s)
        s = self._word(3)
        return Task(kind, f"rev {s}=", s[::-1])

    def sample_n(self, n):
        return [self.sample() for _ in range(n)]

    def _word(self, n):
        return "".join(chr(ord("a") + self.rng.below(26)) for _ in range(n))

    def corrupt(self, task):
        if task.kind in ("add", "max"):
            v = int(task.answer)
            delta = 1 + self.rng.below(3)
            sign = 1 if self.rng.bool(0.5) else -1
            c = v + sign * delta
            if c < 0 or c == v:
                c = v + delta
            return str(c)
        chars = list(task.answer)
        if self.rng.bool(0.7) or len(chars) < 2:
            if self.rng.bool(0.5):
                chars.append(chr(ord("a") + self.rng.below(26)))
            elif len(chars) >= 2:
                chars.pop()
            else:
                chars.append("x")
        else:
            i = self.rng.below(len(chars) - 1)
            chars[i], chars[i + 1] = chars[i + 1], chars[i]
            if "".join(chars) == task.answer:
                chars[0] = "a" if chars[0] == "z" else "z"
        return "".join(chars)

    def rng_bool(self):
        return self.rng.bool(0.5)


def preference_pair(gen, prompt_width, seq):
    task = gen.sample()
    wrong = gen.corrupt(task)

    def mk(answer):
        row = task.prompt_tokens(prompt_width) + encode(answer + "\n")
        assert len(row) <= seq
        idx = len(row) - 1
        return row + [PAD] * (seq - len(row)), idx

    chosen, cidx = mk(task.answer)
    rejected, ridx = mk(wrong)
    return chosen, rejected, cidx, ridx


def verifier_example(gen, prompt_width, seq):
    task = gen.sample()
    correct = gen.rng_bool()
    answer = task.answer if correct else gen.corrupt(task)
    verdict = "yes" if correct else "no"
    row = task.prompt_tokens(prompt_width) + encode(f"{answer} V:")
    vstart = len(row)
    row = row + encode(verdict + "\n")
    vend = len(row)
    assert len(row) <= seq
    row = row + [PAD] * (seq - len(row))
    m = [0.0] * seq
    for i in range(vstart, vend):
        m[i] = 1.0
    return row, m, correct
