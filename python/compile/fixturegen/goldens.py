"""Golden input/output pairs for the committed fixture artifacts.

Inputs are *derived*, not stored: `golden_input` is a deterministic
integer-hash recipe implemented identically in Rust
(`rust/tests/hlo_golden.rs::golden_input`) — keep the two in sync.
Outputs are computed with **jax** (`model.py` / `ref.py`, the same
functions `aot.py` lowers), so the Rust interpreter is differentially
tested against jax on every CI run without CI ever running Python.
`init_*` has no jax counterpart (jax PRNG lowers to a CPU custom-call);
its goldens come from the Python evaluator mirror instead.
"""

from __future__ import annotations

import numpy as np

import jax

from .. import model
from ..kernels import ref
from . import hlo_eval
from .validate import model_config, unflatten


def _hash(i, j):
    return ((i * 1000003 + j) * 2654435761) & 0xFFFFFFFF


def _unit(u):
    return (u >> 8) / 16777216.0


def golden_input(cfg, index, name, shape, dtype):
    """Deterministic input for input slot `index` of an artifact.
    Mirror of the Rust implementation — change both or neither."""
    n = 1
    for d in shape:
        n *= d
    base = name.rsplit("/", 1)[-1]
    if dtype == "u32":
        return np.uint32(42)
    if dtype == "i32":
        if base == "pos":
            return np.int32(cfg.prompt_len)
        hi = cfg.max_seq - 1 if base.endswith("idx") else cfg.vocab
        vals = [_hash(index, j) % hi for j in range(n)]
        return np.array(vals, np.int32).reshape(shape)
    # f32
    scalars = {"step": 3.0, "lr": 1e-3, "clip_eps": 0.2,
               "kl_coef": 0.03, "ent_coef": 0.01}
    if base in scalars:
        return np.float32(scalars[base])
    vals = np.empty(n, np.float64)
    for j in range(n):
        vals[j] = _unit(_hash(index, j))
    if name.startswith("v/"):
        # Adam second moments must be non-negative
        out = 1e-4 * vals + 1e-8
        return out.astype(np.float32).reshape(shape)
    if base == "mask":
        out = (np.array([_hash(index, j) & 3 for j in range(n)]) != 0)
        return out.astype(np.float32).reshape(shape)
    if base in ("old_logp", "ref_logp"):
        out = -2.0 * vals - 0.05
    elif base in ("adv", "returns", "q", "k", "v"):
        out = 2.0 * vals - 1.0
    elif base in ("cache_k", "cache_v"):
        out = 0.1 * vals - 0.05
    elif name.rsplit("/", 1)[-1] in ("ln1_g", "ln2_g") or base == "lnf_g":
        out = 1.0 + 0.01 * (vals - 0.5)
    else:
        out = 0.04 * vals - 0.02
    return out.astype(np.float32).reshape(shape)


def jax_reference(cfg, name, ins):
    """Run the jax counterpart of artifact `name` on `ins` (flat list)."""
    mcfg = model_config(cfg)
    np17 = 17

    def tree(xs):
        return unflatten(mcfg, xs, False)

    def stree(xs):
        return unflatten(mcfg, xs, True)

    def flat(t):
        return [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(t)]

    if name == "fwd_logits":
        return [np.asarray(model.logits_fn(mcfg, tree(ins[:np17]), ins[np17]))]
    if name == "logprob":
        lg = model.logits_fn(mcfg, tree(ins[:np17]), ins[np17])
        return [np.asarray(ref.token_logprob_ref(lg, ins[np17]))]
    if name == "value_score":
        return [np.asarray(model.values_fn(mcfg, stree(ins[:np17]), ins[np17]))]
    if name == "reward_score":
        return [np.asarray(model.reward_score(mcfg, stree(ins[:np17]),
                                              ins[np17], ins[np17 + 1]))]
    if name == "prefill":
        out = model.prefill(mcfg, tree(ins[:np17]), ins[np17])
        return [np.asarray(x) for x in out]
    if name == "decode_step":
        out = model.decode_step(mcfg, tree(ins[:np17]), ins[np17],
                                ins[np17 + 1], ins[np17 + 2], ins[np17 + 3])
        return [np.asarray(x) for x in out]
    if name == "policy_grad":
        g, loss, kl, ent, cf = model.policy_grad(
            mcfg, tree(ins[:np17]), *ins[np17:])
        return flat(g) + [np.float32(loss), np.float32(kl),
                          np.float32(ent), np.float32(cf)]
    if name == "sft_grad":
        g, loss = model.sft_grad(mcfg, tree(ins[:np17]), *ins[np17:])
        return flat(g) + [np.float32(loss)]
    if name == "critic_grad":
        g, loss = model.critic_grad(mcfg, stree(ins[:np17]), *ins[np17:])
        return flat(g) + [np.float32(loss)]
    if name == "bt_grad":
        g, loss, acc = model.bt_grad(mcfg, stree(ins[:np17]), *ins[np17:])
        return flat(g) + [np.float32(loss), np.float32(acc)]
    if name in ("adam_policy", "adam_scalar"):
        t = tree if name == "adam_policy" else stree
        p, m, v = model.adam_apply(
            mcfg, t(ins[:np17]), t(ins[np17:2 * np17]),
            t(ins[2 * np17:3 * np17]), t(ins[3 * np17:4 * np17]),
            ins[4 * np17], ins[4 * np17 + 1])
        return flat(p) + flat(m) + flat(v)
    if name == "train_step":
        out = model.train_step(mcfg, tree(ins[:np17]),
                               tree(ins[np17:2 * np17]),
                               tree(ins[2 * np17:3 * np17]), *ins[3 * np17:])
        return flat(out[0]) + flat(out[1]) + flat(out[2]) + [
            np.float32(out[3]), np.float32(out[4]), np.float32(out[5]),
            np.float32(out[6])]
    return None  # init_*: no jax counterpart


def golden_json(cfg, name, module, ins_specs):
    ins = [golden_input(cfg, i, n, s, d)
           for i, (n, s, d) in enumerate(ins_specs)]
    want = jax_reference(cfg, name, ins)
    source = "jax"
    if want is None:
        want = hlo_eval.evaluate(module, ins)
        source = "hlo_eval"
    else:
        # cross-check the evaluator mirror against jax right here
        got = hlo_eval.evaluate(module, ins)
        err = max(float(np.max(np.abs(np.asarray(a, np.float32) - w)))
                  if np.asarray(a).size else 0.0
                  for a, w in zip(got, want))
        assert err < 5e-4, f"{name}: hlo_eval vs jax {err}"
    outs = []
    for w in want:
        w = np.asarray(w)
        dt = {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(w.dtype)]
        data = ", ".join(repr(float(x)) if dt == "f32" else str(int(x))
                         for x in w.reshape(-1))
        shape = ", ".join(str(d) for d in w.shape)
        outs.append(f'{{"dtype": "{dt}", "shape": [{shape}], '
                    f'"data": [{data}]}}')
    return ('{\n"artifact": "%s",\n"source": "%s",\n"atol": 5e-5,\n'
            '"rtol": 5e-4,\n"outputs": [\n %s\n]\n}\n'
            % (name, source, ",\n ".join(outs)))
