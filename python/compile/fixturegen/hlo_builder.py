"""HLO op-graph builder + text emission.

The emitted text uses exactly the instruction/attribute spellings that
`rust/src/runtime/hlo/parser.rs` handles (and that XLA's own text parser
accepts): shape-prefixed operands, `dimensions={...}`, `slice={[a:b]}`,
`padding=l_hx...`, `to_apply=%reduce_add`, dot dimension-number attributes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Shape:
    dtype: str  # f32 | s32 | u32 | pred
    dims: tuple

    def text(self) -> str:
        return f"{self.dtype}[{','.join(str(d) for d in self.dims)}]"

    @property
    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n


def sh(dtype, *dims):
    return Shape(dtype, tuple(int(d) for d in dims))


@dataclass
class Node:
    op: str
    operands: list
    shape: Shape
    attrs: dict = field(default_factory=dict)


def _f32_lit(v: float) -> str:
    v = float(v)
    if math.isinf(v):
        return "inf" if v > 0 else "-inf"
    import numpy as np

    # shortest decimal that round-trips through f32
    return repr(float(np.float32(v)))


class Graph:
    def __init__(self):
        self.nodes: list[Node] = []
        self.n_params = 0

    def _push(self, op, operands, shape, **attrs):
        self.nodes.append(Node(op, list(operands), shape, attrs))
        return len(self.nodes) - 1

    def dims(self, a):
        return self.nodes[a].shape.dims

    def dtype(self, a):
        return self.nodes[a].shape.dtype

    # -- leaves -------------------------------------------------------------

    def param(self, dtype, dims):
        i = self.n_params
        self.n_params += 1
        return self._push("parameter", [], sh(dtype, *dims), index=i)

    def c_f32(self, v):
        return self._push("constant", [], sh("f32"), value=float(v))

    def c_s32(self, v):
        return self._push("constant", [], sh("s32"), value=int(v))

    def c_u32(self, v):
        return self._push("constant", [], sh("u32"), value=int(v) & 0xFFFFFFFF)

    def full_f32(self, v, dims):
        return self.broadcast(self.c_f32(v), [], dims)

    def iota(self, dtype, dims, dim):
        assert 0 <= dim < len(dims)
        return self._push("iota", [], sh(dtype, *dims), dim=dim)

    # -- elementwise --------------------------------------------------------

    def _ew2(self, op, a, b):
        assert self.nodes[a].shape == self.nodes[b].shape, (
            f"{op}: {self.nodes[a].shape} vs {self.nodes[b].shape}")
        return self._push(op, [a, b], self.nodes[a].shape)

    def add(self, a, b):
        return self._ew2("add", a, b)

    def sub(self, a, b):
        return self._ew2("subtract", a, b)

    def mul(self, a, b):
        return self._ew2("multiply", a, b)

    def div(self, a, b):
        return self._ew2("divide", a, b)

    def max(self, a, b):
        return self._ew2("maximum", a, b)

    def min(self, a, b):
        return self._ew2("minimum", a, b)

    def pow(self, a, b):
        return self._ew2("power", a, b)

    def xor(self, a, b):
        return self._ew2("xor", a, b)

    def shl(self, a, b):
        return self._ew2("shift-left", a, b)

    def shr(self, a, b):
        return self._ew2("shift-right-logical", a, b)

    def _ew1(self, op, a):
        return self._push(op, [a], self.nodes[a].shape)

    def neg(self, a):
        return self._ew1("negate", a)

    def abs(self, a):
        return self._ew1("abs", a)

    def exp(self, a):
        return self._ew1("exponential", a)

    def log(self, a):
        return self._ew1("log", a)

    def tanh(self, a):
        return self._ew1("tanh", a)

    def rsqrt(self, a):
        return self._ew1("rsqrt", a)

    def sqrt(self, a):
        return self._ew1("sqrt", a)

    def sin(self, a):
        return self._ew1("sine", a)

    def cos(self, a):
        return self._ew1("cosine", a)

    def compare(self, direction, a, b):
        assert self.dims(a) == self.dims(b)
        return self._push("compare", [a, b], sh("pred", *self.dims(a)),
                          direction=direction)

    def select(self, p, a, b):
        assert self.dims(p) == self.dims(a) == self.dims(b)
        return self._push("select", [p, a, b], self.nodes[a].shape)

    def convert(self, a, to):
        return self._push("convert", [a], sh(to, *self.dims(a)))

    # -- shape ops ----------------------------------------------------------

    def broadcast(self, a, dims_map, out_dims):
        dims_map = list(dims_map)
        assert len(dims_map) == len(self.dims(a))
        assert all(x < y for x, y in zip(dims_map, dims_map[1:])), dims_map
        for i, d in enumerate(dims_map):
            assert out_dims[d] == self.dims(a)[i]
        return self._push("broadcast", [a], sh(self.dtype(a), *out_dims),
                          dims=dims_map)

    def reshape(self, a, out_dims):
        assert self.nodes[a].shape.size == sh("f32", *out_dims).size
        return self._push("reshape", [a], sh(self.dtype(a), *out_dims))

    def transpose(self, a, perm):
        out = [self.dims(a)[p] for p in perm]
        return self._push("transpose", [a], sh(self.dtype(a), *out),
                          perm=list(perm))

    def slice(self, a, spec):
        for (s, l), d in zip(spec, self.dims(a)):
            assert 0 <= s <= l <= d
        out = [l - s for (s, l) in spec]
        return self._push("slice", [a], sh(self.dtype(a), *out),
                          spec=[tuple(x) for x in spec])

    def concat(self, parts, dim):
        out = list(self.dims(parts[0]))
        out[dim] = sum(self.dims(p)[dim] for p in parts)
        return self._push("concatenate", list(parts),
                          sh(self.dtype(parts[0]), *out), dim=dim)

    def pad_zero(self, a, low, high):
        zero = self.c_f32(0.0)
        out = [d + lo + hi for d, lo, hi in zip(self.dims(a), low, high)]
        return self._push("pad", [a, zero], sh(self.dtype(a), *out),
                          low=list(low), high=list(high))

    def _reduce(self, op, a, dims):
        out = [d for i, d in enumerate(self.dims(a)) if i not in dims]
        return self._push(op, [a], sh(self.dtype(a), *out), dims=sorted(dims))

    def reduce_add(self, a, dims):
        return self._reduce("reduce_add", a, list(dims))

    def reduce_max(self, a, dims):
        return self._reduce("reduce_max", a, list(dims))

    def dot_general(self, lhs, rhs, lb, rb, lc, rc):
        ld, rd = self.dims(lhs), self.dims(rhs)
        for a, b in zip(lc, rc):
            assert ld[a] == rd[b], "dot contract mismatch"
        for a, b in zip(lb, rb):
            assert ld[a] == rd[b], "dot batch mismatch"
        out = [ld[i] for i in lb]
        out += [ld[i] for i in range(len(ld)) if i not in lb and i not in lc]
        out += [rd[i] for i in range(len(rd)) if i not in rb and i not in rc]
        return self._push("dot", [lhs, rhs], sh("f32", *out),
                          lb=list(lb), rb=list(rb), lc=list(lc), rc=list(rc))

    def matmul(self, lhs, rhs):
        return self.dot_general(lhs, rhs, [], [], [len(self.dims(lhs)) - 1], [0])

    def dyn_slice(self, a, starts, sizes):
        assert len(starts) == len(self.dims(a))
        return self._push("dynamic-slice", [a] + list(starts),
                          sh(self.dtype(a), *sizes), sizes=list(sizes))

    def dyn_update_slice(self, a, update, starts):
        assert len(starts) == len(self.dims(a))
        return self._push("dynamic-update-slice", [a, update] + list(starts),
                          self.nodes[a].shape)

    # -- emission -----------------------------------------------------------

    def emit_hlo(self, module_name, outputs):
        live = [False] * len(self.nodes)
        stack = list(outputs)
        while stack:
            i = stack.pop()
            if live[i]:
                continue
            live[i] = True
            stack.extend(self.nodes[i].operands)
        for i, n in enumerate(self.nodes):
            if n.op == "parameter":
                live[i] = True

        uses_add = any(live[i] and n.op == "reduce_add"
                       for i, n in enumerate(self.nodes))
        uses_max = any(live[i] and n.op == "reduce_max"
                       for i, n in enumerate(self.nodes))

        out = [f"HloModule {module_name}"]
        if uses_add:
            out.append("""
%reduce_add (ra_lhs: f32[], ra_rhs: f32[]) -> f32[] {
  %ra_lhs = f32[] parameter(0)
  %ra_rhs = f32[] parameter(1)
  ROOT %ra_out = f32[] add(f32[] %ra_lhs, f32[] %ra_rhs)
}""")
        if uses_max:
            out.append("""
%reduce_max (rm_lhs: f32[], rm_rhs: f32[]) -> f32[] {
  %rm_lhs = f32[] parameter(0)
  %rm_rhs = f32[] parameter(1)
  ROOT %rm_out = f32[] maximum(f32[] %rm_lhs, f32[] %rm_rhs)
}""")

        params = sorted(
            (n.attrs["index"], i) for i, n in enumerate(self.nodes)
            if n.op == "parameter")
        sig = ", ".join(f"p{idx}: {self.nodes[i].shape.text()}"
                        for idx, i in params)
        out_sig = ", ".join(self.nodes[o].shape.text() for o in outputs)
        out.append(f"\nENTRY %entry ({sig}) -> ({out_sig}) {{")
        for i, n in enumerate(self.nodes):
            if live[i]:
                out.append("  " + self._instr_text(i, n))
        tuple_ops = ", ".join(f"{self.nodes[o].shape.text()} %v{o}"
                              for o in outputs)
        out.append(f"  ROOT %result = ({out_sig}) tuple({tuple_ops})")
        out.append("}")
        return "\n".join(out) + "\n"

    def _opn(self, i):
        return f"{self.nodes[i].shape.text()} %v{i}"

    def _instr_text(self, i, n):
        s = n.shape.text()
        ops = ", ".join(self._opn(o) for o in n.operands)
        dl = lambda d: ",".join(str(x) for x in d)  # noqa: E731
        op = n.op
        if op == "parameter":
            return f"%v{i} = {s} parameter({n.attrs['index']})"
        if op == "constant":
            v = n.attrs["value"]
            lit = _f32_lit(v) if n.shape.dtype == "f32" else str(v)
            return f"%v{i} = {s} constant({lit})"
        if op == "compare":
            return f"%v{i} = {s} compare({ops}), direction={n.attrs['direction']}"
        if op == "broadcast":
            return f"%v{i} = {s} broadcast({ops}), dimensions={{{dl(n.attrs['dims'])}}}"
        if op == "transpose":
            return f"%v{i} = {s} transpose({ops}), dimensions={{{dl(n.attrs['perm'])}}}"
        if op == "slice":
            spec = ", ".join(f"[{a}:{b}]" for a, b in n.attrs["spec"])
            return f"%v{i} = {s} slice({ops}), slice={{{spec}}}"
        if op == "concatenate":
            return f"%v{i} = {s} concatenate({ops}), dimensions={{{n.attrs['dim']}}}"
        if op == "pad":
            spec = "x".join(f"{lo}_{hi}" for lo, hi in
                            zip(n.attrs["low"], n.attrs["high"]))
            return f"%v{i} = {s} pad({ops}), padding={spec}"
        if op in ("reduce_add", "reduce_max"):
            init = "0" if op == "reduce_add" else "-inf"
            body = op
            src = self._opn(n.operands[0])
            return (f"%vc{i} = f32[] constant({init})\n"
                    f"  %v{i} = {s} reduce({src}, f32[] %vc{i}), "
                    f"dimensions={{{dl(n.attrs['dims'])}}}, to_apply=%{body}")
        if op == "dot":
            attrs = []
            if n.attrs["lb"]:
                attrs.append(f"lhs_batch_dims={{{dl(n.attrs['lb'])}}}")
                attrs.append(f"rhs_batch_dims={{{dl(n.attrs['rb'])}}}")
            attrs.append(f"lhs_contracting_dims={{{dl(n.attrs['lc'])}}}")
            attrs.append(f"rhs_contracting_dims={{{dl(n.attrs['rc'])}}}")
            return f"%v{i} = {s} dot({ops}), {', '.join(attrs)}"
        if op == "iota":
            return f"%v{i} = {s} iota(), iota_dimension={n.attrs['dim']}"
        if op == "dynamic-slice":
            return (f"%v{i} = {s} dynamic-slice({ops}), "
                    f"dynamic_slice_sizes={{{dl(n.attrs['sizes'])}}}")
        return f"%v{i} = {s} {op}({ops})"
