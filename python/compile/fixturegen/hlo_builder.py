"""HLO op-graph builder + text emission.

The emitted text uses exactly the instruction/attribute spellings that
`rust/src/runtime/hlo/parser.rs` handles (and that XLA's own text parser
accepts): shape-prefixed operands, `dimensions={...}`, `slice={[a:b]}`,
`padding=l_hx...`, `to_apply=%reduce_add`, dot dimension-number attributes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Shape:
    dtype: str  # f32 | s32 | u32 | pred
    dims: tuple

    def text(self) -> str:
        return f"{self.dtype}[{','.join(str(d) for d in self.dims)}]"

    @property
    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n


def sh(dtype, *dims):
    return Shape(dtype, tuple(int(d) for d in dims))


@dataclass(frozen=True)
class TupleShape:
    """Shape of a tuple-valued instruction (`while` results)."""

    parts: tuple  # of Shape

    def text(self) -> str:
        return "(" + ", ".join(p.text() for p in self.parts) + ")"


@dataclass
class Node:
    op: str
    operands: list
    shape: Shape
    attrs: dict = field(default_factory=dict)


def _f32_lit(v: float) -> str:
    v = float(v)
    if math.isinf(v):
        return "inf" if v > 0 else "-inf"
    import numpy as np

    # shortest decimal that round-trips through f32
    return repr(float(np.float32(v)))


class Graph:
    def __init__(self):
        self.nodes: list[Node] = []
        self.n_params = 0

    def _push(self, op, operands, shape, **attrs):
        self.nodes.append(Node(op, list(operands), shape, attrs))
        return len(self.nodes) - 1

    def dims(self, a):
        return self.nodes[a].shape.dims

    def dtype(self, a):
        return self.nodes[a].shape.dtype

    # -- leaves -------------------------------------------------------------

    def param(self, dtype, dims):
        i = self.n_params
        self.n_params += 1
        return self._push("parameter", [], sh(dtype, *dims), index=i)

    def c_f32(self, v):
        return self._push("constant", [], sh("f32"), value=float(v))

    def c_s32(self, v):
        return self._push("constant", [], sh("s32"), value=int(v))

    def c_u32(self, v):
        return self._push("constant", [], sh("u32"), value=int(v) & 0xFFFFFFFF)

    def full_f32(self, v, dims):
        return self.broadcast(self.c_f32(v), [], dims)

    def iota(self, dtype, dims, dim):
        assert 0 <= dim < len(dims)
        return self._push("iota", [], sh(dtype, *dims), dim=dim)

    # -- elementwise --------------------------------------------------------

    def _ew2(self, op, a, b):
        assert self.nodes[a].shape == self.nodes[b].shape, (
            f"{op}: {self.nodes[a].shape} vs {self.nodes[b].shape}")
        return self._push(op, [a, b], self.nodes[a].shape)

    def add(self, a, b):
        return self._ew2("add", a, b)

    def sub(self, a, b):
        return self._ew2("subtract", a, b)

    def mul(self, a, b):
        return self._ew2("multiply", a, b)

    def div(self, a, b):
        return self._ew2("divide", a, b)

    def max(self, a, b):
        return self._ew2("maximum", a, b)

    def min(self, a, b):
        return self._ew2("minimum", a, b)

    def pow(self, a, b):
        return self._ew2("power", a, b)

    def xor(self, a, b):
        return self._ew2("xor", a, b)

    def shl(self, a, b):
        return self._ew2("shift-left", a, b)

    def shr(self, a, b):
        return self._ew2("shift-right-logical", a, b)

    def and_(self, a, b):
        return self._ew2("and", a, b)

    def or_(self, a, b):
        return self._ew2("or", a, b)

    def _ew1(self, op, a):
        return self._push(op, [a], self.nodes[a].shape)

    def neg(self, a):
        return self._ew1("negate", a)

    def abs(self, a):
        return self._ew1("abs", a)

    def exp(self, a):
        return self._ew1("exponential", a)

    def log(self, a):
        return self._ew1("log", a)

    def tanh(self, a):
        return self._ew1("tanh", a)

    def rsqrt(self, a):
        return self._ew1("rsqrt", a)

    def sqrt(self, a):
        return self._ew1("sqrt", a)

    def sin(self, a):
        return self._ew1("sine", a)

    def cos(self, a):
        return self._ew1("cosine", a)

    def compare(self, direction, a, b):
        assert self.dims(a) == self.dims(b)
        return self._push("compare", [a, b], sh("pred", *self.dims(a)),
                          direction=direction)

    def select(self, p, a, b):
        assert self.dims(p) == self.dims(a) == self.dims(b)
        return self._push("select", [p, a, b], self.nodes[a].shape)

    def convert(self, a, to):
        return self._push("convert", [a], sh(to, *self.dims(a)))

    # -- shape ops ----------------------------------------------------------

    def broadcast(self, a, dims_map, out_dims):
        dims_map = list(dims_map)
        assert len(dims_map) == len(self.dims(a))
        assert all(x < y for x, y in zip(dims_map, dims_map[1:])), dims_map
        for i, d in enumerate(dims_map):
            assert out_dims[d] == self.dims(a)[i]
        return self._push("broadcast", [a], sh(self.dtype(a), *out_dims),
                          dims=dims_map)

    def reshape(self, a, out_dims):
        assert self.nodes[a].shape.size == sh("f32", *out_dims).size
        return self._push("reshape", [a], sh(self.dtype(a), *out_dims))

    def transpose(self, a, perm):
        out = [self.dims(a)[p] for p in perm]
        return self._push("transpose", [a], sh(self.dtype(a), *out),
                          perm=list(perm))

    def slice(self, a, spec):
        for (s, l), d in zip(spec, self.dims(a)):
            assert 0 <= s <= l <= d
        out = [l - s for (s, l) in spec]
        return self._push("slice", [a], sh(self.dtype(a), *out),
                          spec=[tuple(x) for x in spec])

    def concat(self, parts, dim):
        out = list(self.dims(parts[0]))
        out[dim] = sum(self.dims(p)[dim] for p in parts)
        return self._push("concatenate", list(parts),
                          sh(self.dtype(parts[0]), *out), dim=dim)

    def pad_zero(self, a, low, high):
        zero = self.c_f32(0.0)
        out = [d + lo + hi for d, lo, hi in zip(self.dims(a), low, high)]
        return self._push("pad", [a, zero], sh(self.dtype(a), *out),
                          low=list(low), high=list(high))

    def _reduce(self, op, a, dims):
        out = [d for i, d in enumerate(self.dims(a)) if i not in dims]
        return self._push(op, [a], sh(self.dtype(a), *out), dims=sorted(dims))

    def reduce_add(self, a, dims):
        return self._reduce("reduce_add", a, list(dims))

    def reduce_max(self, a, dims):
        return self._reduce("reduce_max", a, list(dims))

    def reduce_min(self, a, dims):
        assert self.dtype(a) == "s32", "reduce_min emitted for s32 only"
        return self._reduce("reduce_min", a, list(dims))

    def sort(self, a, dim):
        """Descending sort along `dim` (GT comparator, f32 only)."""
        assert self.dtype(a) == "f32"
        assert 0 <= dim < len(self.dims(a))
        return self._push("sort", [a], self.nodes[a].shape, dim=dim)

    def rng_bits(self, state, dims):
        """Counter-based PRNG: u32 bits keyed off a scalar u32 state.

        bits[j] = hash_u32(state + j) over the row-major linear index j,
        matching the fixture `_hash`/lowbias32 scheme. The state operand is
        a plain scalar; callers advance it in-graph with `add`.
        """
        assert self.dtype(state) == "u32" and self.dims(state) == ()
        return self._push("rng-bit-generator", [state], sh("u32", *dims))

    def rng_uniform(self, a, b, dims):
        """Old-style `rng` op, uniform distribution (deterministic counter)."""
        assert self.dims(a) == () == self.dims(b)
        assert self.dtype(a) == "f32" == self.dtype(b)
        return self._push("rng", [a, b], sh("f32", *dims))

    def scatter_add(self, operand, indices, updates, uwd, iwd, sdod, ivd):
        """Scatter with an add update computation (jax embedding-grad form)."""
        assert self.dtype(operand) == "f32" == self.dtype(updates)
        assert self.dtype(indices) == "s32"
        return self._push("scatter", [operand, indices, updates],
                          self.nodes[operand].shape,
                          uwd=list(uwd), iwd=list(iwd), sdod=list(sdod),
                          ivd=int(ivd))

    def while_(self, operands, cond, cond_root, body, body_outs, label):
        """Loop-carried flattened state: N operands, cond/body take N params.

        `cond`/`body` are separate Graphs whose parameters mirror the operand
        shapes 1:1; the body returns the next state as `body_outs` (emitted as
        a ROOT tuple), the cond returns a scalar pred at `cond_root`.
        """
        parts = tuple(self.nodes[o].shape for o in operands)
        assert cond.n_params == len(operands), "while: cond param count"
        assert body.n_params == len(operands), "while: body param count"
        body_parts = tuple(body.nodes[o].shape for o in body_outs)
        assert parts == body_parts, "while: body output shapes must match state"
        assert cond.nodes[cond_root].shape == sh("pred"), "while: cond root pred[]"
        return self._push("while", list(operands), TupleShape(parts),
                          cond=(cond, cond_root), body=(body, list(body_outs)),
                          label=str(label))

    def gte(self, a, k):
        shp = self.nodes[a].shape
        assert isinstance(shp, TupleShape)
        return self._push("get-tuple-element", [a], shp.parts[k], index=int(k))

    def dot_general(self, lhs, rhs, lb, rb, lc, rc):
        ld, rd = self.dims(lhs), self.dims(rhs)
        for a, b in zip(lc, rc):
            assert ld[a] == rd[b], "dot contract mismatch"
        for a, b in zip(lb, rb):
            assert ld[a] == rd[b], "dot batch mismatch"
        out = [ld[i] for i in lb]
        out += [ld[i] for i in range(len(ld)) if i not in lb and i not in lc]
        out += [rd[i] for i in range(len(rd)) if i not in rb and i not in rc]
        return self._push("dot", [lhs, rhs], sh("f32", *out),
                          lb=list(lb), rb=list(rb), lc=list(lc), rc=list(rc))

    def matmul(self, lhs, rhs):
        return self.dot_general(lhs, rhs, [], [], [len(self.dims(lhs)) - 1], [0])

    def dyn_slice(self, a, starts, sizes):
        assert len(starts) == len(self.dims(a))
        return self._push("dynamic-slice", [a] + list(starts),
                          sh(self.dtype(a), *sizes), sizes=list(sizes))

    def dyn_update_slice(self, a, update, starts):
        assert len(starts) == len(self.dims(a))
        return self._push("dynamic-update-slice", [a, update] + list(starts),
                          self.nodes[a].shape)

    # -- emission -----------------------------------------------------------

    def _liveness(self, outputs):
        live = [False] * len(self.nodes)
        stack = list(outputs)
        while stack:
            i = stack.pop()
            if live[i]:
                continue
            live[i] = True
            stack.extend(self.nodes[i].operands)
        for i, n in enumerate(self.nodes):
            if n.op == "parameter":
                live[i] = True
        return live

    def _collect_helpers(self, live, acc):
        for i, n in enumerate(self.nodes):
            if not live[i]:
                continue
            if n.op in ("reduce_add", "reduce_max"):
                acc.add(n.op)
            elif n.op == "reduce_min":
                acc.add("reduce_min_s32")
            elif n.op == "sort":
                acc.add("sort_gt_f32")
            elif n.op == "scatter":
                acc.add("scatter_add_f32")

    def emit_hlo(self, module_name, outputs):
        live = self._liveness(outputs)
        subs = []  # (name, graph, outputs, value-prefix, tuple_root)
        for i, n in enumerate(self.nodes):
            if live[i] and n.op == "while":
                lbl = n.attrs["label"]
                cg, croot = n.attrs["cond"]
                bg, bouts = n.attrs["body"]
                assert not any(m.op == "while" for m in cg.nodes + bg.nodes), \
                    "nested while is not supported"
                subs.append((f"{lbl}_cond", cg, [croot], "c", False))
                subs.append((f"{lbl}_body", bg, list(bouts), "w", True))

        helpers = set()
        self._collect_helpers(live, helpers)
        sub_lives = []
        for _, g, souts, _, _ in subs:
            sl = g._liveness(souts)
            g._collect_helpers(sl, helpers)
            sub_lives.append(sl)

        out = [f"HloModule {module_name}"]
        for key, block in _HELPER_BLOCKS:
            if key in helpers:
                out.append(block)
        for (name, g, souts, vp, tup), sl in zip(subs, sub_lives):
            out.append(g._computation_text(name, souts, vp, tup, sl))
        out.append(self._entry_text(outputs, live))
        return "\n".join(out) + "\n"

    def _param_sig(self):
        params = sorted(
            (n.attrs["index"], i) for i, n in enumerate(self.nodes)
            if n.op == "parameter")
        return ", ".join(f"p{idx}: {self.nodes[i].shape.text()}"
                         for idx, i in params)

    def _entry_text(self, outputs, live):
        out_sig = ", ".join(self.nodes[o].shape.text() for o in outputs)
        lines = [f"\nENTRY %entry ({self._param_sig()}) -> ({out_sig}) {{"]
        for i, n in enumerate(self.nodes):
            if live[i]:
                lines.append("  " + self._instr_text(i, n, "v"))
        tuple_ops = ", ".join(f"{self.nodes[o].shape.text()} %v{o}"
                              for o in outputs)
        lines.append(f"  ROOT %result = ({out_sig}) tuple({tuple_ops})")
        lines.append("}")
        return "\n".join(lines)

    def _computation_text(self, name, outputs, vp, tuple_root, live):
        if tuple_root:
            ret = "(" + ", ".join(self.nodes[o].shape.text()
                                  for o in outputs) + ")"
        else:
            assert len(outputs) == 1
            assert not self.nodes[outputs[0]].op.startswith("reduce_"), \
                "non-tuple computation root must be a single-line instruction"
            ret = self.nodes[outputs[0]].shape.text()
        lines = [f"\n%{name} ({self._param_sig()}) -> {ret} {{"]
        for i, n in enumerate(self.nodes):
            if live[i]:
                prefix = "ROOT " if (not tuple_root and i == outputs[0]) else ""
                lines.append("  " + prefix + self._instr_text(i, n, vp))
        if tuple_root:
            tuple_ops = ", ".join(f"{self.nodes[o].shape.text()} %{vp}{o}"
                                  for o in outputs)
            lines.append(f"  ROOT %{vp}root = {ret} tuple({tuple_ops})")
        lines.append("}")
        return "\n".join(lines)

    def _opn(self, i, vp="v"):
        return f"{self.nodes[i].shape.text()} %{vp}{i}"

    def _instr_text(self, i, n, vp):
        s = n.shape.text()
        ops = ", ".join(self._opn(o, vp) for o in n.operands)
        dl = lambda d: ",".join(str(x) for x in d)  # noqa: E731
        op = n.op
        if op == "parameter":
            return f"%{vp}{i} = {s} parameter({n.attrs['index']})"
        if op == "constant":
            v = n.attrs["value"]
            lit = _f32_lit(v) if n.shape.dtype == "f32" else str(v)
            return f"%{vp}{i} = {s} constant({lit})"
        if op == "compare":
            return f"%{vp}{i} = {s} compare({ops}), direction={n.attrs['direction']}"
        if op == "broadcast":
            return f"%{vp}{i} = {s} broadcast({ops}), dimensions={{{dl(n.attrs['dims'])}}}"
        if op == "transpose":
            return f"%{vp}{i} = {s} transpose({ops}), dimensions={{{dl(n.attrs['perm'])}}}"
        if op == "slice":
            spec = ", ".join(f"[{a}:{b}]" for a, b in n.attrs["spec"])
            return f"%{vp}{i} = {s} slice({ops}), slice={{{spec}}}"
        if op == "concatenate":
            return f"%{vp}{i} = {s} concatenate({ops}), dimensions={{{n.attrs['dim']}}}"
        if op == "pad":
            spec = "x".join(f"{lo}_{hi}" for lo, hi in
                            zip(n.attrs["low"], n.attrs["high"]))
            return f"%{vp}{i} = {s} pad({ops}), padding={spec}"
        if op in ("reduce_add", "reduce_max", "reduce_min"):
            dt = n.shape.dtype
            if op == "reduce_add":
                init, body = "0", "reduce_add"
            elif op == "reduce_max":
                init, body = "-inf", "reduce_max"
            else:
                init, body = "2147483647", "reduce_min_s32"
            src = self._opn(n.operands[0], vp)
            return (f"%{vp}c{i} = {dt}[] constant({init})\n"
                    f"  %{vp}{i} = {s} reduce({src}, {dt}[] %{vp}c{i}), "
                    f"dimensions={{{dl(n.attrs['dims'])}}}, to_apply=%{body}")
        if op == "dot":
            attrs = []
            if n.attrs["lb"]:
                attrs.append(f"lhs_batch_dims={{{dl(n.attrs['lb'])}}}")
                attrs.append(f"rhs_batch_dims={{{dl(n.attrs['rb'])}}}")
            attrs.append(f"lhs_contracting_dims={{{dl(n.attrs['lc'])}}}")
            attrs.append(f"rhs_contracting_dims={{{dl(n.attrs['rc'])}}}")
            return f"%{vp}{i} = {s} dot({ops}), {', '.join(attrs)}"
        if op == "iota":
            return f"%{vp}{i} = {s} iota(), iota_dimension={n.attrs['dim']}"
        if op == "dynamic-slice":
            return (f"%{vp}{i} = {s} dynamic-slice({ops}), "
                    f"dynamic_slice_sizes={{{dl(n.attrs['sizes'])}}}")
        if op == "sort":
            return (f"%{vp}{i} = {s} sort({ops}), "
                    f"dimensions={{{n.attrs['dim']}}}, to_apply=%sort_gt_f32")
        if op == "rng-bit-generator":
            return f"%{vp}{i} = {s} rng-bit-generator({ops}), algorithm=rng_default"
        if op == "rng":
            return f"%{vp}{i} = {s} rng({ops}), distribution=rng_uniform"
        if op == "scatter":
            return (f"%{vp}{i} = {s} scatter({ops}), "
                    f"update_window_dims={{{dl(n.attrs['uwd'])}}}, "
                    f"inserted_window_dims={{{dl(n.attrs['iwd'])}}}, "
                    f"scatter_dims_to_operand_dims={{{dl(n.attrs['sdod'])}}}, "
                    f"index_vector_dim={n.attrs['ivd']}, "
                    f"to_apply=%scatter_add_f32")
        if op == "while":
            lbl = n.attrs["label"]
            return (f"%{vp}{i} = {s} while({ops}), "
                    f"condition=%{lbl}_cond, body=%{lbl}_body")
        if op == "get-tuple-element":
            return f"%{vp}{i} = {s} get-tuple-element({ops}), index={n.attrs['index']}"
        return f"%{vp}{i} = {s} {op}({ops})"


_HELPER_BLOCKS = [
    ("reduce_add", """
%reduce_add (ra_lhs: f32[], ra_rhs: f32[]) -> f32[] {
  %ra_lhs = f32[] parameter(0)
  %ra_rhs = f32[] parameter(1)
  ROOT %ra_out = f32[] add(f32[] %ra_lhs, f32[] %ra_rhs)
}"""),
    ("reduce_max", """
%reduce_max (rm_lhs: f32[], rm_rhs: f32[]) -> f32[] {
  %rm_lhs = f32[] parameter(0)
  %rm_rhs = f32[] parameter(1)
  ROOT %rm_out = f32[] maximum(f32[] %rm_lhs, f32[] %rm_rhs)
}"""),
    ("reduce_min_s32", """
%reduce_min_s32 (rms_lhs: s32[], rms_rhs: s32[]) -> s32[] {
  %rms_lhs = s32[] parameter(0)
  %rms_rhs = s32[] parameter(1)
  ROOT %rms_out = s32[] minimum(s32[] %rms_lhs, s32[] %rms_rhs)
}"""),
    ("sort_gt_f32", """
%sort_gt_f32 (sg_lhs: f32[], sg_rhs: f32[]) -> pred[] {
  %sg_lhs = f32[] parameter(0)
  %sg_rhs = f32[] parameter(1)
  ROOT %sg_out = pred[] compare(f32[] %sg_lhs, f32[] %sg_rhs), direction=GT
}"""),
    ("scatter_add_f32", """
%scatter_add_f32 (sa_lhs: f32[], sa_rhs: f32[]) -> f32[] {
  %sa_lhs = f32[] parameter(0)
  %sa_rhs = f32[] parameter(1)
  ROOT %sa_out = f32[] add(f32[] %sa_lhs, f32[] %sa_rhs)
}"""),
]
