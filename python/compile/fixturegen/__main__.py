"""Regenerate the committed fixture artifact sets + goldens.

    python -m compile.fixturegen [--out ../rust/tests/fixtures]

Steps: emit HLO artifact sets for the `tiny` and `synthetic` configs,
differentially validate every artifact against the jax model, prove the
learning-threshold test scenarios pass, then write the artifact text,
manifests and golden JSONs the Rust test tier consumes.
"""

from __future__ import annotations

import argparse
import os

from . import goldens as goldens_mod
from . import hlo_eval, simulate, validate
from .modelgen import SYNTHETIC, TINY, emit_artifacts, manifest_json

# tiny goldens are limited to small-output artifacts (inputs are derived
# from the recipe either way; outputs for grad/train artifacts would be
# ~0.5 MB of JSON each at tiny scale)
TINY_GOLDENS = ["logprob", "value_score", "reward_score"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    default_out = os.path.join(os.path.dirname(__file__),
                               "../../../rust/tests/fixtures")
    ap.add_argument("--out", default=default_out)
    ap.add_argument("--skip-simulate", action="store_true",
                    help="skip the learning-threshold simulations (slow)")
    args = ap.parse_args()
    out = os.path.abspath(args.out)

    for cfg in (SYNTHETIC, TINY):
        print(f"== {cfg.name}: emitting ...")
        arts = emit_artifacts(cfg)
        tol = 5e-4 if cfg.name == "synthetic" else 2e-3
        print(f"== {cfg.name}: validating against jax/model.py ...")
        validate.validate(cfg, arts, tol=tol, verbose=False)

        set_dir = os.path.join(out, "artifacts", cfg.name)
        os.makedirs(set_dir, exist_ok=True)
        total = 0
        for name, text, _, _ in arts:
            path = os.path.join(set_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            total += len(text)
        with open(os.path.join(set_dir, "manifest.json"), "w") as f:
            f.write(manifest_json(cfg, arts))
        print(f"== {cfg.name}: wrote {len(arts)} artifacts "
              f"({total / 1e6:.2f} MB HLO text) -> {set_dir}")

        gold_dir = os.path.join(out, "goldens", cfg.name)
        os.makedirs(gold_dir, exist_ok=True)
        # generate_rollout has no jax reference (jax PRNG lowers to a
        # custom-call); it is pinned by the stepwise differential in
        # validate.py and the Rust fused-vs-stepwise bit-identity test.
        wanted = (TINY_GOLDENS if cfg.name == "tiny"
                  else [name for name, _, _, _ in arts
                        if name != "generate_rollout"])
        n = 0
        for name, text, ins, _ in arts:
            if name not in wanted:
                continue
            module = hlo_eval.Module(text)
            j = goldens_mod.golden_json(cfg, name, module, ins)
            with open(os.path.join(gold_dir, f"{name}.json"), "w") as f:
                f.write(j)
            n += 1
        print(f"== {cfg.name}: wrote {n} golden files -> {gold_dir}")

    if not args.skip_simulate:
        print("== simulating the Rust suites' learning-threshold tests ...")
        simulate.main()


if __name__ == "__main__":
    main()
