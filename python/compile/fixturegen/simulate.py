"""Simulate the Rust test suites' learning-threshold scenarios against the
emitted artifacts (via `hlo_eval`, the Python mirror of the Rust
interpreter), using exact ports of the Rust RNG/task generators.

Run at fixture-generation time to prove the committed `tiny` set can pass:
* runtime_integration: `train_step_reduces_loss_and_updates_params`,
  `bt_grad_learns_preference`;
* coordinator_integration: `bt_pretraining_fits_preferences` (acc ≥ 0.75),
  `verifier_pretraining_beats_chance` (acc > 0.65), SFT warm-start loss
  decrease.
"""

from __future__ import annotations

import time

import numpy as np

from . import hlo_eval, rustrng
from .modelgen import TINY, emit_artifacts


class Engine:
    def __init__(self, cfg, arts):
        self.cfg = cfg
        self.mods = {name: hlo_eval.Module(text) for name, text, _, _ in arts}

    def run(self, name, inputs):
        return hlo_eval.evaluate(self.mods[name], inputs)


def fixed_tokens(b, s):
    return np.array([[(i * 2654435761) % 256 for i in range(r * s, (r + 1) * s)]
                     for r in range(b)], np.int32)


class TrainState:
    def __init__(self, engine, params, artifact):
        self.e = engine
        self.params = params
        self.m = [np.zeros_like(p) for p in params]
        self.v = [np.zeros_like(p) for p in params]
        self.step = 0
        self.artifact = artifact

    def apply(self, grads, lr):
        self.step += 1
        out = self.e.run(self.artifact,
                         self.params + self.m + self.v + list(grads)
                         + [np.float32(self.step), np.float32(lr)])
        n = len(self.params)
        self.params, self.m, self.v = out[:n], out[n:2 * n], out[2 * n:3 * n]


def sim_bt_fixed_batch(e):
    """runtime_integration::bt_grad_learns_preference."""
    cfg = e.cfg
    b, s = cfg.batch, cfg.max_seq
    chosen = fixed_tokens(b, s)
    rejected = (255 - chosen).astype(np.int32)
    idx = np.full((b,), s - 1, np.int32)
    params = e.run("init_scalar", [np.uint32(9)])
    st = TrainState(e, params, "adam_scalar")
    first, last = None, (0.0, 0.0)
    for _ in range(12):
        out = e.run("bt_grad", st.params + [chosen, rejected, idx, idx])
        loss, acc = float(out[-2]), float(out[-1])
        st.apply(out[:-2], 3e-3)
        if first is None:
            first = loss
        last = (loss, acc)
    assert last[0] < first, (last, first)
    assert last[1] == 1.0, last
    return first, last


def sim_train_bt(e, kinds, steps, lr, seed):
    """pretrain.rs::train_bt."""
    cfg = e.cfg
    b, s, p = cfg.batch, cfg.max_seq, cfg.prompt_len
    st = TrainState(e, e.run("init_scalar", [np.uint32(seed)]), "adam_scalar")
    gen = rustrng.TaskGen(kinds, seed)
    losses, acc = [], 0.0
    for _ in range(steps):
        ch, rj, ci, ri = [], [], [], []
        for _ in range(b):
            c, r, a, d = rustrng.preference_pair(gen, p, s)
            ch.append(c)
            rj.append(r)
            ci.append(a)
            ri.append(d)
        out = e.run("bt_grad", st.params + [
            np.array(ch, np.int32), np.array(rj, np.int32),
            np.array(ci, np.int32), np.array(ri, np.int32)])
        acc = float(out[-1])
        losses.append(float(out[-2]))
        st.apply(out[:-2], lr)
    return losses, acc


def verifier_accuracy(e, params, kinds, seed):
    cfg = e.cfg
    b, s, p, v = cfg.batch, cfg.max_seq, cfg.prompt_len, cfg.vocab
    gen = rustrng.TaskGen(kinds, seed)
    correct = total = 0
    for _ in range(4):
        rows, qends, labels = [], [], []
        for _ in range(b):
            row, mask, label = rustrng.verifier_example(gen, p, s)
            vstart = mask.index(1.0)
            rows.append(row)
            qends.append(vstart - 1)
            labels.append(label)
        blanked = []
        for row, q in zip(rows, qends):
            r = list(row)
            for i in range(q + 1, len(r)):
                r[i] = 0
            blanked.append(r)
        logits = e.run("fwd_logits",
                       params + [np.array(blanked, np.int32)])[0]
        for i in range(b):
            yes = logits[i, qends[i], ord("y")] > logits[i, qends[i], ord("n")]
            correct += int(yes == labels[i])
            total += 1
    return correct / total


def sim_train_verifier(e, kinds, steps, lr, seed):
    """pretrain.rs::train_verifier."""
    cfg = e.cfg
    b, s, p = cfg.batch, cfg.max_seq, cfg.prompt_len
    st = TrainState(e, e.run("init_policy", [np.uint32(seed)]), "adam_policy")
    gen = rustrng.TaskGen(kinds, seed)
    losses = []
    for _ in range(steps):
        rows, masks = [], []
        for _ in range(b):
            row, mask, _ = rustrng.verifier_example(gen, p, s)
            rows.append(row)
            masks.append(mask)
        out = e.run("sft_grad", st.params + [
            np.array(rows, np.int32), np.array(masks, np.float32)])
        losses.append(float(out[-1]))
        st.apply(out[:-1], lr)
    metric = verifier_accuracy(e, st.params, kinds, seed + 1)
    return losses, metric


def sim_train_step_decreases(e):
    """runtime_integration::train_step_reduces_loss_and_updates_params."""
    cfg = e.cfg
    b, s = cfg.batch, cfg.max_seq
    params = e.run("init_policy", [np.uint32(1)])
    tokens = fixed_tokens(b, s)
    ones = np.ones((b, s), np.float32)
    logp = e.run("logprob", params + [tokens])[0]
    st = TrainState(e, params, "adam_policy")
    losses = []
    m = [np.zeros_like(p) for p in params]
    v = [np.zeros_like(p) for p in params]
    cur = params
    for step in range(1, 5):
        out = e.run("train_step", cur + m + v + [
            tokens, ones, ones, logp, logp,
            np.float32(step), np.float32(1e-3), np.float32(0.2),
            np.float32(0.0), np.float32(0.0)])
        n = len(params)
        cur, m, v = out[:n], out[n:2 * n], out[2 * n:3 * n]
        losses.append(float(out[3 * n]))
        assert float(out[3 * n + 3]) >= 0.0
    assert losses[-1] < losses[0], losses
    _ = st
    return losses


def sim_sft_decreases(e, seed=17, steps=4, lr=1.5e-3):
    """controller.rs::sft_step over the tiny_cfg task mix."""
    cfg = e.cfg
    b, s, p = cfg.batch, cfg.max_seq, cfg.prompt_len
    gen = rustrng.TaskGen(["add", "max", "copy"], seed)
    st = TrainState(e, e.run("init_policy", [np.uint32(seed)]), "adam_policy")
    losses = []
    for _ in range(steps):
        rows, masks = [], []
        for _ in range(b):
            t = gen.sample()
            row, mask = t.demonstration(p, s)
            rows.append(row)
            masks.append(mask)
        out = e.run("sft_grad", st.params + [
            np.array(rows, np.int32), np.array(masks, np.float32)])
        losses.append(float(out[-1]))
        st.apply(out[:-1], lr)
    assert losses[-1] < losses[0], losses
    return losses


def sim_fused_equals_split(e):
    """runtime_integration::policy_grad_plus_adam_equals_fused (tolerance
    here is float-level; in Rust both paths share one interpreter and are
    bit-identical)."""
    cfg = e.cfg
    b, s = cfg.batch, cfg.max_seq
    params = e.run("init_policy", [np.uint32(3)])
    tokens = fixed_tokens(b, s)
    ones = np.ones((b, s), np.float32)
    logp = e.run("logprob", params + [tokens])[0]
    zeros = [np.zeros_like(p) for p in params]
    fused = e.run("train_step", params + zeros + zeros + [
        tokens, ones, ones, logp, logp, np.float32(1.0), np.float32(1e-3),
        np.float32(0.2), np.float32(0.01), np.float32(0.0)])
    gout = e.run("policy_grad", params + [
        tokens, ones, ones, logp, logp,
        np.float32(0.2), np.float32(0.01), np.float32(0.0)])
    st = TrainState(e, params, "adam_policy")
    st.apply(gout[:len(params)], 1e-3)
    n = len(params)
    err = max(float(np.max(np.abs(a - c))) for a, c in
              zip(fused[:n], st.params))
    assert err < 1e-6, err
    return err


def main():
    cfg = TINY
    print("emitting tiny artifacts ...")
    arts = emit_artifacts(cfg)
    e = Engine(cfg, arts)

    t0 = time.time()
    losses = sim_train_step_decreases(e)
    dt = (time.time() - t0) / 4
    print(f"train_step losses {['%.4f' % l for l in losses]} "
          f"({dt * 1e3:.0f} ms/step in numpy)")

    err = sim_fused_equals_split(e)
    print(f"fused == grad+adam, max|Δ| = {err:.2e}")

    first, last = sim_bt_fixed_batch(e)
    print(f"bt fixed batch: loss {first:.4f} -> {last[0]:.4f}, acc {last[1]}")

    losses, acc = sim_train_bt(e, ["copy", "rev"], 60, 2e-3, 7)
    print(f"train_bt(copy,rev,60,2e-3,seed7): loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}, final-batch acc {acc:.3f} (need >= 0.75)")
    assert acc >= 0.75 and losses[-1] < losses[0]

    losses, acc2 = sim_train_bt(e, ["copy", "rev"], 40, 3e-3, 17 + 101)
    print(f"train_bt(copy,rev,40,3e-3,seed118): acc {acc2:.3f} "
          f"(build_rewarder path)")

    sft = sim_sft_decreases(e)
    print(f"sft warm-start losses {['%.4f' % l for l in sft]}")

    t0 = time.time()
    losses, metric = sim_train_verifier(e, ["copy"], 300, 3e-3, 11)
    print(f"train_verifier(copy,300,3e-3,seed11): loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}, accuracy {metric:.3f} (need > 0.65) "
          f"[{time.time() - t0:.0f}s]")
    assert metric > 0.65

    print("all threshold simulations OK")


if __name__ == "__main__":
    main()
