"""Differential validation: emitted HLO artifacts vs the real jax model.

Every artifact is evaluated through `hlo_eval` (the Python mirror of the
Rust interpreter) on deterministic inputs and compared against
`python/compile/model.py` / `kernels/ref.py` executed with jax — the same
functions `aot.py` lowers for the PJRT backend.  This runs once at
fixture-generation time; the committed artifacts are known-good against
jax before the Rust side ever parses them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .. import model
from ..kernels import ref
from . import hlo_eval
from .modelgen import EOS_ID, PAD_ID, SAMPLER_TOP_K, GenConfig


def model_config(cfg: GenConfig) -> ModelConfig:
    return ModelConfig(
        name=cfg.name, vocab=cfg.vocab, d_model=cfg.d_model,
        n_layers=cfg.n_layers, n_heads=cfg.n_heads, d_ff=cfg.d_ff,
        max_seq=cfg.max_seq, prompt_len=cfg.prompt_len, batch=cfg.batch,
        use_pallas=False)


def tree_def(mcfg: ModelConfig, scalar_head: bool):
    shape = jax.eval_shape(
        lambda s: model.init_params(mcfg, s, scalar_head=scalar_head),
        jax.ShapeDtypeStruct((), jnp.uint32))
    return jax.tree_util.tree_structure(shape)


def unflatten(mcfg, flat, scalar_head):
    return jax.tree_util.tree_unflatten(
        tree_def(mcfg, scalar_head), [jnp.asarray(x) for x in flat])


def flatten(tree):
    return [np.asarray(x, dtype=np.float32)
            for x in jax.tree_util.tree_leaves(tree)]


def rand_tree(cfg: GenConfig, rng, scalar_head, scale=0.02):
    out = []
    for path, dims in cfg.tree(scalar_head):
        if path.endswith("_g"):
            out.append(np.ones(dims, np.float32))
        elif path.endswith("_b") or path.startswith("blk/b"):
            out.append((rng.standard_normal(dims) * 0.001).astype(np.float32))
        else:
            out.append((rng.standard_normal(dims) * scale).astype(np.float32))
    return out


def diff(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    if a.shape != b.shape:
        return float("inf")
    return float(np.max(np.abs(a - b))) if a.size else 0.0


def validate(cfg: GenConfig, arts, tol=5e-4, verbose=True):
    """arts: output of modelgen.emit_artifacts.  Raises on mismatch."""
    mcfg = model_config(cfg)
    mods = {name: hlo_eval.Module(text) for name, text, _, _ in arts}
    rng = np.random.default_rng(20260729)
    b, s, p_len, v = cfg.batch, cfg.max_seq, cfg.prompt_len, cfg.vocab

    policy = rand_tree(cfg, rng, False)
    scalar = rand_tree(cfg, rng, True)
    policy_t = unflatten(mcfg, policy, False)
    scalar_t = unflatten(mcfg, scalar, True)
    tokens = rng.integers(0, v, size=(b, s)).astype(np.int32)
    mask = (rng.random((b, s)) < 0.7).astype(np.float32)
    mask[:, 0] = 0.0
    adv = rng.standard_normal((b, s)).astype(np.float32)
    worst = {}

    def check(name, got, want, scale=1.0):
        err = max(diff(g, w) for g, w in zip(got, want)) if got else 0.0
        assert len(got) == len(want), (name, len(got), len(want))
        worst[name] = err
        if verbose:
            print(f"  {name:<14} max|Δ| = {err:.3e}")
        assert err < tol * scale, f"{name}: {err} vs tol {tol * scale}"

    # forward family ------------------------------------------------------
    logits_ref = np.asarray(model.logits_fn(mcfg, policy_t, tokens))
    out = hlo_eval.evaluate(mods["fwd_logits"], policy + [tokens])
    check("fwd_logits", out, [logits_ref])

    lp_ref = np.asarray(ref.token_logprob_ref(jnp.asarray(logits_ref), tokens))
    out = hlo_eval.evaluate(mods["logprob"], policy + [tokens])
    check("logprob", out, [lp_ref])

    vals_ref = np.asarray(model.values_fn(mcfg, scalar_t, tokens))
    out = hlo_eval.evaluate(mods["value_score"], scalar + [tokens])
    check("value_score", out, [vals_ref])

    idx = rng.integers(0, s, size=(b,)).astype(np.int32)
    rs_ref = np.asarray(model.reward_score(mcfg, scalar_t, tokens, idx))
    out = hlo_eval.evaluate(mods["reward_score"], scalar + [tokens, idx])
    check("reward_score", out, [rs_ref])

    qkv = [(rng.standard_normal((b, cfg.n_heads, s, cfg.d_head)) * 0.5)
           .astype(np.float32) for _ in range(3)]
    am_ref = np.asarray(ref.attention_ref(*[jnp.asarray(x) for x in qkv],
                                          causal=True))
    out = hlo_eval.evaluate(mods["attn_micro"], qkv)
    check("attn_micro", out, [am_ref])

    # cached generation ---------------------------------------------------
    prompts = tokens[:, :p_len]
    pl_ref, ck_ref, cv_ref = model.prefill(mcfg, policy_t, prompts)
    out = hlo_eval.evaluate(mods["prefill"], policy + [prompts])
    check("prefill", out, [np.asarray(pl_ref), np.asarray(ck_ref),
                           np.asarray(cv_ref)])
    ck, cv = out[1], out[2]

    tok_step = tokens[:, p_len].astype(np.int32)
    dl_ref, dck_ref, dcv_ref = model.decode_step(
        mcfg, policy_t, jnp.asarray(ck), jnp.asarray(cv),
        jnp.asarray(tok_step), jnp.int32(p_len))
    out = hlo_eval.evaluate(
        mods["decode_step"],
        policy + [ck, cv, tok_step, np.int32(p_len)])
    check("decode_step", out, [np.asarray(dl_ref), np.asarray(dck_ref),
                               np.asarray(dcv_ref)])
    # decode must reproduce the full forward at position p_len
    full_at = logits_ref[:, p_len, :][:, :]
    full_from_prompt = np.asarray(
        model.logits_fn(mcfg, policy_t, tokens))[:, p_len - 1, :]
    assert diff(out[0], np.asarray(dl_ref)) < tol
    _ = full_at, full_from_prompt

    # gradients -----------------------------------------------------------
    old_lp = (lp_ref + rng.standard_normal((b, s)).astype(np.float32) * 0.05)
    ref_lp = (lp_ref + rng.standard_normal((b, s)).astype(np.float32) * 0.05)
    clip, klc, entc = np.float32(0.2), np.float32(0.03), np.float32(0.01)
    g_ref, loss_ref, kl_ref, ent_ref, cf_ref = model.policy_grad(
        mcfg, policy_t, tokens, mask, adv, old_lp, ref_lp, clip, klc, entc)
    out = hlo_eval.evaluate(
        mods["policy_grad"],
        policy + [tokens, mask, adv, old_lp, ref_lp, clip, klc, entc])
    check("policy_grad", out,
          flatten(g_ref) + [np.float32(loss_ref), np.float32(kl_ref),
                            np.float32(ent_ref), np.float32(cf_ref)],
          scale=4.0)

    sg_ref, sloss_ref = model.sft_grad(mcfg, policy_t, tokens, mask)
    out = hlo_eval.evaluate(mods["sft_grad"], policy + [tokens, mask])
    check("sft_grad", out, flatten(sg_ref) + [np.float32(sloss_ref)], scale=4.0)

    returns = rng.standard_normal((b, s)).astype(np.float32)
    cg_ref, closs_ref = model.critic_grad(mcfg, scalar_t, tokens, mask, returns)
    out = hlo_eval.evaluate(mods["critic_grad"],
                            scalar + [tokens, mask, returns])
    check("critic_grad", out, flatten(cg_ref) + [np.float32(closs_ref)],
          scale=4.0)

    rejected = rng.integers(0, v, size=(b, s)).astype(np.int32)
    cidx = np.full((b,), s - 2, np.int32)
    ridx = np.full((b,), s - 3, np.int32)
    bg_ref, bloss_ref, bacc_ref = model.bt_grad(
        mcfg, scalar_t, tokens, rejected, cidx, ridx)
    out = hlo_eval.evaluate(mods["bt_grad"],
                            scalar + [tokens, rejected, cidx, ridx])
    check("bt_grad", out, flatten(bg_ref) + [np.float32(bloss_ref),
                                             np.float32(bacc_ref)], scale=4.0)

    # optimiser -----------------------------------------------------------
    mstate = rand_tree(cfg, rng, False, scale=0.001)
    vstate = [np.abs(x).astype(np.float32) * 0.001 + 1e-6
              for x in rand_tree(cfg, rng, False)]
    gset = rand_tree(cfg, rng, False, scale=0.01)
    step, lr = np.float32(3.0), np.float32(1e-3)
    ap_ref = model.adam_apply(
        mcfg, policy_t, unflatten(mcfg, mstate, False),
        unflatten(mcfg, vstate, False), unflatten(mcfg, gset, False), step, lr)
    out = hlo_eval.evaluate(
        mods["adam_policy"],
        policy + mstate + vstate + gset + [step, lr])
    check("adam_policy", out,
          flatten(ap_ref[0]) + flatten(ap_ref[1]) + flatten(ap_ref[2]))

    ts_ref = model.train_step(
        mcfg, policy_t, unflatten(mcfg, mstate, False),
        unflatten(mcfg, vstate, False), tokens, mask, adv, old_lp, ref_lp,
        step, lr, clip, klc, entc)
    out = hlo_eval.evaluate(
        mods["train_step"],
        policy + mstate + vstate
        + [tokens, mask, adv, old_lp, ref_lp, step, lr, clip, klc, entc])
    check("train_step", out,
          flatten(ts_ref[0]) + flatten(ts_ref[1]) + flatten(ts_ref[2])
          + [np.float32(ts_ref[3]), np.float32(ts_ref[4]),
             np.float32(ts_ref[5]), np.float32(ts_ref[6])], scale=4.0)

    # init sanity (distribution, not jax-matching: jax PRNG lowers to a
    # custom-call the interpreter can't run, so init uses a hash design)
    for name, scalar_head in (("init_policy", False), ("init_scalar", True)):
        out = hlo_eval.evaluate(mods[name], [np.uint32(42)])
        out2 = hlo_eval.evaluate(mods[name], [np.uint32(42)])
        out3 = hlo_eval.evaluate(mods[name], [np.uint32(43)])
        assert all(np.array_equal(a, c) for a, c in zip(out, out2))
        assert any(not np.array_equal(a, c) for a, c in zip(out, out3))
        wq = out[10]  # blk/wq: N(0, 0.02)
        assert abs(float(wq.mean())) < 0.004, wq.mean()
        assert 0.015 < float(wq.std()) < 0.025, wq.std()
        total = sum(int(np.asarray(x).size) for x in out)
        want = (cfg.scalar_param_count() if scalar_head else cfg.param_count())
        assert total == want, (total, want)
        if verbose:
            print(f"  {name:<14} deterministic, std(wq)={wq.std():.4f}")

    # fused rollout: the while-loop artifact must be BIT-identical to a
    # stepwise composition of prefill/decode_step + the counter-based
    # Gumbel-max sampler (the same formula the Rust host sampler uses).
    # No jax reference exists (jax PRNG lowers to a custom-call), so this
    # differential is the pin, mirrored in Rust by rollout_integration.rs.
    seed32 = np.uint32(20260808)
    gtemp = np.float32(0.8)
    fused = hlo_eval.evaluate(mods["generate_rollout"],
                              policy + [prompts, seed32, gtemp])[0]
    ref_rows = _stepwise_rollout(mods, policy, prompts, seed32, gtemp,
                                 SAMPLER_TOP_K, s, v)
    assert np.array_equal(fused, ref_rows), (fused.tolist(),
                                             ref_rows.tolist())
    assert np.array_equal(fused[:, :p_len], prompts)
    for r in range(b):
        gen = fused[r, p_len:]
        eos_at = np.where(gen == EOS_ID)[0]
        if eos_at.size:
            assert np.all(gen[eos_at[0] + 1:] == PAD_ID), gen.tolist()
    worst["generate_rollout"] = 0.0
    if verbose:
        print("  generate_rollout fused == stepwise, bit-identical")

    return worst


def _counter_sample(logits_row, temp, top_k, base, row):
    """One Gumbel-max draw; mirrors the in-graph sampler op-for-op (f32)."""
    v = logits_row.shape[0]
    ctr = np.uint32(base) + np.arange(row * v, (row + 1) * v, dtype=np.uint32)
    bits = hlo_eval._hash_u32(ctr)
    u = ((bits >> np.uint32(8)).astype(np.float32) + np.float32(0.5)) \
        * np.float32(1.0 / 16777216.0)
    gum = -np.log(-np.log(u))
    scores = logits_row / temp + gum
    if 0 < top_k < v:
        thresh = np.sort(logits_row)[::-1][top_k - 1]
        scores = np.where(logits_row >= thresh, scores, np.float32("-inf"))
    return int(np.argmax(scores))  # first index on ties, like the graph


def _stepwise_rollout(mods, policy, prompts, seed32, temp, top_k, s, v):
    """generate_stepwise semantics over the hlo_eval artifacts."""
    b, p = prompts.shape
    logits, ck, cv = hlo_eval.evaluate(mods["prefill"], policy + [prompts])
    rows = [[int(t) for t in prompts[r]] for r in range(b)]
    done = [False] * b
    base = np.uint32((int(seed32) * 0x9E3779B1) & 0xFFFFFFFF)
    for pos in range(p, s):
        toks = []
        for r in range(b):
            if done[r]:
                tok = PAD_ID
            else:
                tok = _counter_sample(logits[r], temp, top_k, base, r)
                if tok == EOS_ID:
                    done[r] = True
            rows[r].append(tok)
            toks.append(tok)
        base = np.uint32((int(base) + b * v) & 0xFFFFFFFF)
        if all(done) or pos == s - 1:
            for r in range(b):
                rows[r].extend([PAD_ID] * (s - len(rows[r])))
            break
        logits, ck, cv = hlo_eval.evaluate(
            mods["decode_step"],
            policy + [ck, cv, np.asarray(toks, np.int32), np.int32(pos)])
    return np.asarray(rows, np.int32)


def main():
    from .modelgen import SYNTHETIC, TINY, emit_artifacts

    for cfg in (SYNTHETIC, TINY):
        print(f"validating '{cfg.name}' against jax/model.py ...")
        arts = emit_artifacts(cfg)
        tol = 5e-4 if cfg.name == "synthetic" else 2e-3
        validate(cfg, arts, tol=tol)
        print(f"  '{cfg.name}' OK")


if __name__ == "__main__":
    main()
