"""Model entry points → HLO artifact sets (the fixture counterpart of
`python/compile/aot.py`).

One definition of the tiny byte-level transformer — the same math as
`python/compile/model.py` (pre-LN blocks, causal attention, tanh-GELU,
layernorm eps 1e-5, ref.py losses, bias-corrected Adam) — built as HLO op
graphs, with gradient artifacts derived by `hlo_autodiff`.  The 17-tensor
flat parameter tree is the sorted-pytree-key order `aot.py` pins in the
manifest, so the Rust coordinator code runs unchanged.

`generate_rollout` is the fused prefill + while(sample → decode) module:
loop-carried state is the flattened 25-tuple [17 params, pos, rows, ck,
cv, logits, rng base, done, temp]; sampling is counter-based Gumbel-max
over `rng-bit-generator` bits (bits[j] = lowbias32(base + j), base0 =
seed·0x9E3779B1, advanced by B·V per step) with the top-k threshold from
a descending `sort`, so fused, stepwise and scheduler paths draw the
same tokens from the same u32 seed.  The baked sampler parameters
(top_k / stop_at_eos) are recorded in the manifest `"sampler"` block.

Init differs from model.py's `jax.random.normal` (which lowers to a CPU
custom-call the interpreter can't execute): parameters are drawn with a
counter-based hash (lowbias32) + Box-Muller expressed in plain HLO ops —
same N(0, 0.02) / depth-scaled-residual distribution, fully deterministic
in the seed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .hlo_autodiff import gradients
from .hlo_builder import Graph


@dataclass(frozen=True)
class GenConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    prompt_len: int
    batch: int
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.0

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    def param_count(self):
        d, v, s, f, l = self.d_model, self.vocab, self.max_seq, self.d_ff, self.n_layers
        per_block = 2 * d + 4 * d * d + 2 * d + d * f + f + f * d + d
        return v * d + s * d + l * per_block + 2 * d + d * v

    def scalar_param_count(self):
        return self.param_count() - self.d_model * self.vocab + self.d_model

    def tree(self, scalar_head: bool):
        d, v, s, f, l = self.d_model, self.vocab, self.max_seq, self.d_ff, self.n_layers
        head = 1 if scalar_head else v
        return [
            ("blk/b1", [l, f]),
            ("blk/b2", [l, d]),
            ("blk/ln1_b", [l, d]),
            ("blk/ln1_g", [l, d]),
            ("blk/ln2_b", [l, d]),
            ("blk/ln2_g", [l, d]),
            ("blk/w1", [l, d, f]),
            ("blk/w2", [l, f, d]),
            ("blk/wk", [l, d, d]),
            ("blk/wo", [l, d, d]),
            ("blk/wq", [l, d, d]),
            ("blk/wv", [l, d, d]),
            ("head", [d, head]),
            ("lnf_b", [d]),
            ("lnf_g", [d]),
            ("pos_emb", [s, d]),
            ("tok_emb", [v, d]),
        ]


TINY = GenConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
                 d_ff=256, max_seq=64, prompt_len=16, batch=4)
SYNTHETIC = GenConfig("synthetic", vocab=32, d_model=8, n_layers=2, n_heads=2,
                      d_ff=16, max_seq=12, prompt_len=4, batch=2)

# Flat-tree indices.
(B1, B2, LN1B, LN1G, LN2B, LN2G, W1, W2, WK, WO, WQ, WV,
 HEAD, LNFB, LNFG, POS, TOK) = range(17)
NP17 = 17

# Mirror of rust data::tokenizer::{PAD, EOS} and the default SamplerConfig
# baked into the fused generate_rollout artifact.
PAD_ID = 0
EOS_ID = 10
SAMPLER_TOP_K = 16
SAMPLER_STOP_AT_EOS = True


class M:
    """Model-graph scaffold: a Graph plus the config it is built for."""

    def __init__(self, cfg: GenConfig):
        self.cfg = cfg
        self.g = Graph()

    def tree_params(self, scalar_head):
        return [self.g.param("f32", dims) for _, dims in self.cfg.tree(scalar_head)]

    # -- building blocks ----------------------------------------------------

    def onehot(self, ids, depth):
        g = self.g
        dims = list(g.dims(ids)) + [depth]
        iota = g.iota("s32", dims, len(dims) - 1)
        idb = g.broadcast(ids, list(range(len(dims) - 1)), dims)
        return g.convert(g.compare("EQ", iota, idb), "f32")

    def layer(self, p, l):
        g = self.g
        dims = list(g.dims(p))
        spec = [(l, l + 1)] + [(0, d) for d in dims[1:]]
        return g.reshape(g.slice(p, spec), dims[1:])

    def layernorm(self, x, gain, bias):
        g = self.g
        dims = list(g.dims(x))
        d = dims[-1]
        last = len(dims) - 1
        kept = list(range(last))
        inv_d = g.full_f32(1.0 / d, dims[:last])
        mu = g.mul(g.reduce_add(x, [last]), inv_d)
        xc = g.sub(x, g.broadcast(mu, kept, dims))
        var = g.mul(g.reduce_add(g.mul(xc, xc), [last]), inv_d)
        inv = g.rsqrt(g.add(var, g.full_f32(1e-5, dims[:last])))
        norm = g.mul(xc, g.broadcast(inv, kept, dims))
        ng = g.mul(norm, g.broadcast(gain, [last], dims))
        return g.add(ng, g.broadcast(bias, [last], dims))

    def gelu(self, x):
        g = self.g
        dims = list(g.dims(x))
        x3 = g.mul(g.mul(x, x), x)
        inner = g.add(x, g.mul(x3, g.full_f32(0.044715, dims)))
        t = g.tanh(g.mul(inner, g.full_f32(math.sqrt(2.0 / math.pi), dims)))
        tp1 = g.add(t, g.full_f32(1.0, dims))
        return g.mul(g.mul(x, g.full_f32(0.5, dims)), tp1)

    def split_heads(self, x):
        g = self.g
        b, t, _ = g.dims(x)
        h, dh = self.cfg.n_heads, self.cfg.d_head
        return g.transpose(g.reshape(x, [b, t, h, dh]), [0, 2, 1, 3])

    def merge_heads(self, x):
        g = self.g
        b, h, t, dh = g.dims(x)
        return g.reshape(g.transpose(x, [0, 2, 1, 3]), [b, t, h * dh])

    def proj(self, x, w):
        return self.g.dot_general(x, w, [], [], [2], [0])

    def attention(self, q, k, v, qpos):
        """softmax(q·kᵀ/√Dh + causal/pos mask)·v.

        qpos: ("static", offset) — query rows at offset+[0..T);
              ("dynamic", node)  — all rows at one runtime position.
        """
        g = self.g
        qd = list(g.dims(q))
        s = g.dims(k)[2]
        sd = [qd[0], qd[1], qd[2], s]
        raw = g.dot_general(q, k, [0, 1], [0, 1], [3], [3])
        scores = g.mul(raw, g.full_f32(1.0 / math.sqrt(self.cfg.d_head), sd))
        kpos = g.iota("s32", sd, 3)
        kind, val = qpos
        if kind == "static":
            qp = g.iota("s32", sd, 2)
            if val:
                qp = g.add(qp, g.broadcast(g.c_s32(val), [], sd))
        else:
            qp = g.broadcast(val, [], sd)
        keep = g.compare("LE", kpos, qp)
        masked = g.select(keep, scores, g.full_f32(-1.0e30, sd))
        mx = g.reduce_max(masked, [3])
        shifted = g.sub(masked, g.broadcast(mx, [0, 1, 2], sd))
        ex = g.exp(shifted)
        den = g.reduce_add(ex, [3])
        p = g.div(ex, g.broadcast(den, [0, 1, 2], sd))
        return g.dot_general(p, v, [0, 1], [0, 1], [3], [2])

    def embed(self, params, tokens, pos):
        g = self.g
        b, t = g.dims(tokens)
        d = self.cfg.d_model
        oh = self.onehot(tokens, self.cfg.vocab)
        emb = g.dot_general(oh, params[TOK], [], [], [2], [0])
        kind, val = pos
        if kind == "static":
            ps = g.slice(params[POS], [(val, val + t), (0, d)])
        else:
            ps = g.dyn_slice(params[POS], [val, g.c_s32(0)], [t, d])
        return g.add(emb, g.broadcast(ps, [1, 2], [b, t, d]))

    def ffn(self, params, h, l):
        g = self.g
        dims = list(g.dims(h))
        x = self.layernorm(h, self.layer(params[LN2G], l), self.layer(params[LN2B], l))
        up = self.proj(x, self.layer(params[W1], l))
        f = self.cfg.d_ff
        upb = g.add(up, g.broadcast(self.layer(params[B1], l), [2],
                                    [dims[0], dims[1], f]))
        act = self.gelu(upb)
        down = self.proj(act, self.layer(params[W2], l))
        downb = g.add(down, g.broadcast(self.layer(params[B2], l), [2], dims))
        return g.add(h, downb)

    def block(self, params, h, l):
        g = self.g
        x = self.layernorm(h, self.layer(params[LN1G], l), self.layer(params[LN1B], l))
        q = self.split_heads(self.proj(x, self.layer(params[WQ], l)))
        k = self.split_heads(self.proj(x, self.layer(params[WK], l)))
        v = self.split_heads(self.proj(x, self.layer(params[WV], l)))
        attn = self.attention(q, k, v, ("static", 0))
        ao = self.proj(self.merge_heads(attn), self.layer(params[WO], l))
        h = g.add(h, ao)
        return self.ffn(params, h, l)

    def trunk(self, params, tokens):
        h = self.embed(params, tokens, ("static", 0))
        for l in range(self.cfg.n_layers):
            h = self.block(params, h, l)
        return self.layernorm(h, params[LNFG], params[LNFB])

    def logits(self, params, tokens):
        return self.proj(self.trunk(params, tokens), params[HEAD])

    def values(self, params, tokens):
        td = list(self.g.dims(tokens))
        return self.g.reshape(self.logits(params, tokens), td)

    def log_softmax(self, logits):
        g = self.g
        dims = list(g.dims(logits))
        last = len(dims) - 1
        kept = list(range(last))
        mx = g.reduce_max(logits, [last])
        shifted = g.sub(logits, g.broadcast(mx, kept, dims))
        den = g.reduce_add(g.exp(shifted), [last])
        return g.sub(shifted, g.broadcast(g.log(den), kept, dims))

    def token_logprob(self, logits, tokens):
        g = self.g
        b, s, v = g.dims(logits)
        lp = self.log_softmax(logits)
        lp_prev = g.slice(lp, [(0, b), (0, s - 1), (0, v)])
        tok_next = g.slice(tokens, [(0, b), (1, s)])
        oh = self.onehot(tok_next, v)
        scored = g.reduce_add(g.mul(lp_prev, oh), [2])
        return g.concat([g.full_f32(0.0, [b, 1]), scored], 1)

    def entropy(self, logits):
        g = self.g
        last = len(g.dims(logits)) - 1
        lp = self.log_softmax(logits)
        return g.neg(g.reduce_add(g.mul(g.exp(lp), lp), [last]))

    def masked_mean(self, x, mask):
        g = self.g
        alld = list(range(len(g.dims(x))))
        num = g.reduce_add(g.mul(x, mask), alld)
        den = g.max(g.reduce_add(mask, alld), g.c_f32(1.0))
        return g.div(num, den)

    def mean_all(self, x):
        g = self.g
        dims = g.dims(x)
        n = 1
        for d in dims:
            n *= d
        return g.mul(g.reduce_add(x, list(range(len(dims)))), g.c_f32(1.0 / n))

    def reward_score(self, params, tokens, idx):
        g = self.g
        b, s = g.dims(tokens)
        v = self.values(params, tokens)
        iota = g.iota("s32", [b, s], 1)
        oh = g.convert(g.compare("EQ", iota, g.broadcast(idx, [0], [b, s])), "f32")
        return g.reduce_add(g.mul(v, oh), [1])

    def ppo_loss(self, logits, lp, old_lp, ref_lp, adv, mask, clip, klc, entc):
        g = self.g
        dims = list(g.dims(lp))
        ratio = g.exp(g.sub(lp, old_lp))
        unclipped = g.mul(ratio, adv)
        one = g.full_f32(1.0, dims)
        epsb = g.broadcast(clip, [], dims)
        clipped = g.mul(g.min(g.max(ratio, g.sub(one, epsb)), g.add(one, epsb)), adv)
        pg = g.neg(g.min(unclipped, clipped))
        lr = g.sub(ref_lp, lp)
        kl = g.sub(g.sub(g.exp(lr), lr), one)
        ent = self.entropy(logits)
        pg_m = self.masked_mean(pg, mask)
        kl_m = self.masked_mean(kl, mask)
        ent_m = self.masked_mean(ent, mask)
        loss = g.sub(g.add(pg_m, g.mul(klc, kl_m)), g.mul(entc, ent_m))
        outside = g.compare("GT", g.abs(g.sub(ratio, one)), epsb)
        clipfrac = self.masked_mean(g.convert(outside, "f32"), mask)
        return loss, kl_m, ent_m, clipfrac

    def adam(self, p, m, v, grads, step, lr):
        g = self.g
        cfg = self.cfg
        b1c, b2c = g.c_f32(cfg.adam_b1), g.c_f32(cfg.adam_b2)
        one = g.c_f32(1.0)
        c1 = g.sub(one, g.pow(b1c, step))
        c2 = g.sub(one, g.pow(b2c, step))
        po, mo, vo = [], [], []
        for i in range(NP17):
            dims = list(g.dims(p[i]))
            mn = g.add(g.mul(g.broadcast(b1c, [], dims), m[i]),
                       g.mul(g.full_f32(1.0 - cfg.adam_b1, dims), grads[i]))
            vn = g.add(g.mul(g.broadcast(b2c, [], dims), v[i]),
                       g.mul(g.full_f32(1.0 - cfg.adam_b2, dims),
                             g.mul(grads[i], grads[i])))
            mhat = g.div(mn, g.broadcast(c1, [], dims))
            vhat = g.div(vn, g.broadcast(c2, [], dims))
            den = g.add(g.sqrt(vhat), g.full_f32(cfg.adam_eps, dims))
            upd = g.div(mhat, den)
            if cfg.weight_decay:
                upd = g.add(upd, g.mul(g.full_f32(cfg.weight_decay, dims), p[i]))
            pn = g.sub(p[i], g.mul(g.broadcast(lr, [], dims), upd))
            po.append(pn)
            mo.append(mn)
            vo.append(vn)
        return po, mo, vo

    # -- KV-cached path -----------------------------------------------------

    def cached_block(self, params, h, l, ck, cv, qpos, write):
        g = self.g
        x = self.layernorm(h, self.layer(params[LN1G], l), self.layer(params[LN1B], l))
        q = self.split_heads(self.proj(x, self.layer(params[WQ], l)))
        k = self.split_heads(self.proj(x, self.layer(params[WK], l)))
        v = self.split_heads(self.proj(x, self.layer(params[WV], l)))
        if write[0] == "prefix":
            t = g.dims(k)[2]
            s = g.dims(ck)[2]
            high = [0, 0, s - t, 0]
            ck = g.pad_zero(k, [0, 0, 0, 0], high)
            cv = g.pad_zero(v, [0, 0, 0, 0], high)
        else:
            zero = g.c_s32(0)
            pos = write[1]
            ck = g.dyn_update_slice(ck, k, [zero, zero, pos, zero])
            cv = g.dyn_update_slice(cv, v, [zero, zero, pos, zero])
        attn = self.attention(q, ck, cv, qpos)
        ao = self.proj(self.merge_heads(attn), self.layer(params[WO], l))
        h = g.add(h, ao)
        return self.ffn(params, h, l), ck, cv

    def forward_cached(self, params, tokens, caches, pos):
        g = self.g
        cfg = self.cfg
        b, t = g.dims(tokens)
        ln, hn, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head
        h = self.embed(params, tokens, pos)
        cks, cvs = [], []
        for l in range(ln):
            if caches is None:
                ck_l = g.full_f32(0.0, [b, hn, s, dh])
                cv_l = g.full_f32(0.0, [b, hn, s, dh])
                write = ("prefix",)
            else:
                ck_l = self.layer(caches[0], l)
                cv_l = self.layer(caches[1], l)
                write = ("dynamic", pos[1])
            h, ckn, cvn = self.cached_block(params, h, l, ck_l, cv_l, pos, write)
            cks.append(g.reshape(ckn, [1, b, hn, s, dh]))
            cvs.append(g.reshape(cvn, [1, b, hn, s, dh]))
        ck_out = g.concat(cks, 0)
        cv_out = g.concat(cvs, 0)
        d = cfg.d_model
        h_last = g.reshape(g.slice(h, [(0, b), (t - 1, t), (0, d)]), [b, 1, d])
        hf = self.layernorm(h_last, params[LNFG], params[LNFB])
        logits = g.reshape(self.proj(hf, params[HEAD]), [b, cfg.vocab])
        return logits, ck_out, cv_out

    # -- init ---------------------------------------------------------------

    def hash_u32(self, x):
        g = self.g
        dims = list(g.dims(x))
        z = x
        for mul, shift in ((0xED5AD4BB, 17), (0xAC4C1B51, 11), (0x31848BAB, 15)):
            zs = g.shr(z, g.broadcast(g.c_u32(shift), [], dims))
            z = g.mul(g.xor(z, zs), g.broadcast(g.c_u32(mul), [], dims))
        zs = g.shr(z, g.broadcast(g.c_u32(14), [], dims))
        return g.xor(z, zs)

    def to_unit(self, h):
        g = self.g
        dims = list(g.dims(h))
        top = g.shr(h, g.broadcast(g.c_u32(8), [], dims))
        f = g.convert(top, "f32")
        fh = g.add(f, g.full_f32(0.5, dims))
        return g.mul(fh, g.full_f32(1.0 / 16777216.0, dims))

    def normal_init(self, seed, stream, dims, std):
        g = self.g
        n = 1
        for d in dims:
            n *= d
        idx = g.iota("u32", [n], 0)
        x2 = g.mul(idx, g.broadcast(g.c_u32(2), [], [n]))
        sg = g.mul(g.broadcast(seed, [], [n]),
                   g.broadcast(g.c_u32(0x9E3779B1), [], [n]))
        streamc = (stream * 0x85EBCA6B + 1) & 0xFFFFFFFF
        base = g.add(sg, g.broadcast(g.c_u32(streamc), [], [n]))
        e1 = g.add(x2, base)
        e2 = g.add(g.add(x2, g.broadcast(g.c_u32(1), [], [n])), base)
        u1 = self.to_unit(self.hash_u32(e1))
        u2 = self.to_unit(self.hash_u32(e2))
        r = g.sqrt(g.mul(g.log(u1), g.full_f32(-2.0, [n])))
        ang = g.mul(u2, g.full_f32(2.0 * math.pi, [n]))
        z = g.mul(r, g.cos(ang))
        return g.reshape(g.mul(z, g.full_f32(std, [n])), dims)

    def init_tree(self, seed, scalar_head):
        cfg = self.cfg
        std = 0.02
        res_std = std / math.sqrt(2.0 * cfg.n_layers)
        out = []
        for i, (path, dims) in enumerate(cfg.tree(scalar_head)):
            if path in ("blk/ln1_g", "blk/ln2_g", "lnf_g"):
                out.append(self.g.full_f32(1.0, dims))
            elif path in ("blk/ln1_b", "blk/ln2_b", "lnf_b", "blk/b1", "blk/b2"):
                out.append(self.g.full_f32(0.0, dims))
            elif path == "pos_emb":
                out.append(self.normal_init(seed, i, dims, 0.01))
            elif path in ("blk/wo", "blk/w2"):
                out.append(self.normal_init(seed, i, dims, res_std))
            else:
                out.append(self.normal_init(seed, i, dims, std))
        return out


# ---------------------------------------------------------------------------
# Entry-point emission
# ---------------------------------------------------------------------------


def _tree_io(cfg, prefix, scalar):
    return [(f"{prefix}/{p}", dims, "f32") for p, dims in cfg.tree(scalar)]


def emit_artifacts(cfg: GenConfig):
    """Returns [(name, hlo_text, inputs, outputs)] with manifest I/O specs."""
    b, s, p_len, v = cfg.batch, cfg.max_seq, cfg.prompt_len, cfg.vocab
    cache = [cfg.n_layers, b, cfg.n_heads, s, cfg.d_head]
    tok_bs = ("tokens", [b, s], "i32")
    mask_bs = ("mask", [b, s], "f32")
    sc = lambda name: (name, [], "f32")  # noqa: E731
    arts = []

    def art(m, name, outs, ins, out_specs):
        arts.append((name, m.g.emit_hlo(name, outs), ins, out_specs))

    for name, scalar in (("init_policy", False), ("init_scalar", True)):
        m = M(cfg)
        seed = m.g.param("u32", [])
        tree = m.init_tree(seed, scalar)
        outs = [(f"out/{p}", d, "f32") for p, d in cfg.tree(scalar)]
        art(m, name, tree, [("seed", [], "u32")], outs)

    m = M(cfg)
    params = m.tree_params(False)
    tokens = m.g.param("s32", [b, s])
    art(m, "fwd_logits", [m.logits(params, tokens)],
        _tree_io(cfg, "params", False) + [tok_bs],
        [("out", [b, s, v], "f32")])

    m = M(cfg)
    params = m.tree_params(False)
    tokens = m.g.param("s32", [b, s])
    lp = m.token_logprob(m.logits(params, tokens), tokens)
    art(m, "logprob", [lp],
        _tree_io(cfg, "params", False) + [tok_bs],
        [("out", [b, s], "f32")])

    m = M(cfg)
    params = m.tree_params(False)
    tokens = m.g.param("s32", [b, p_len])
    logits, ck, cv = m.forward_cached(params, tokens, None, ("static", 0))
    art(m, "prefill", [logits, ck, cv],
        _tree_io(cfg, "params", False) + [("tokens", [b, p_len], "i32")],
        [("out/0", [b, v], "f32"), ("out/1", cache, "f32"),
         ("out/2", cache, "f32")])

    m = M(cfg)
    params = m.tree_params(False)
    ck_in = m.g.param("f32", cache)
    cv_in = m.g.param("f32", cache)
    tok = m.g.param("s32", [b])
    pos = m.g.param("s32", [])
    tok2 = m.g.reshape(tok, [b, 1])
    logits, ckn, cvn = m.forward_cached(params, tok2, (ck_in, cv_in),
                                        ("dynamic", pos))
    art(m, "decode_step", [logits, ckn, cvn],
        _tree_io(cfg, "params", False) + [
            ("cache_k", cache, "f32"), ("cache_v", cache, "f32"),
            ("token", [b], "i32"), ("pos", [], "i32")],
        [("out/0", [b, v], "f32"), ("out/1", cache, "f32"),
         ("out/2", cache, "f32")])

    m = M(cfg)
    params = m.tree_params(True)
    tokens = m.g.param("s32", [b, s])
    art(m, "value_score", [m.values(params, tokens)],
        _tree_io(cfg, "params", True) + [tok_bs],
        [("out", [b, s], "f32")])

    m = M(cfg)
    params = m.tree_params(True)
    tokens = m.g.param("s32", [b, s])
    idx = m.g.param("s32", [b])
    art(m, "reward_score", [m.reward_score(params, tokens, idx)],
        _tree_io(cfg, "params", True) + [tok_bs, ("last_idx", [b], "i32")],
        [("out", [b], "f32")])

    m = M(cfg)
    params = m.tree_params(False)
    tokens = m.g.param("s32", [b, s])
    mask = m.g.param("f32", [b, s])
    adv = m.g.param("f32", [b, s])
    old_lp = m.g.param("f32", [b, s])
    ref_lp = m.g.param("f32", [b, s])
    clip = m.g.param("f32", [])
    klc = m.g.param("f32", [])
    entc = m.g.param("f32", [])
    logits = m.logits(params, tokens)
    lp = m.token_logprob(logits, tokens)
    loss, kl, ent, cf = m.ppo_loss(logits, lp, old_lp, ref_lp, adv, mask,
                                   clip, klc, entc)
    grads = gradients(m.g, loss, params)
    art(m, "policy_grad", grads + [loss, kl, ent, cf],
        _tree_io(cfg, "params", False) + [
            tok_bs, mask_bs, ("adv", [b, s], "f32"),
            ("old_logp", [b, s], "f32"), ("ref_logp", [b, s], "f32"),
            sc("clip_eps"), sc("kl_coef"), sc("ent_coef")],
        _tree_io(cfg, "out/grads", False) + [
            sc("out/loss"), sc("out/kl"), sc("out/entropy"), sc("out/clipfrac")])

    m = M(cfg)
    params = m.tree_params(False)
    tokens = m.g.param("s32", [b, s])
    mask = m.g.param("f32", [b, s])
    lp = m.token_logprob(m.logits(params, tokens), tokens)
    loss = m.g.neg(m.masked_mean(lp, mask))
    grads = gradients(m.g, loss, params)
    art(m, "sft_grad", grads + [loss],
        _tree_io(cfg, "params", False) + [tok_bs, mask_bs],
        _tree_io(cfg, "out/grads", False) + [sc("out/loss")])

    m = M(cfg)
    params = m.tree_params(True)
    tokens = m.g.param("s32", [b, s])
    mask = m.g.param("f32", [b, s])
    returns = m.g.param("f32", [b, s])
    vals = m.values(params, tokens)
    dv = m.g.sub(vals, returns)
    loss = m.masked_mean(m.g.mul(dv, dv), mask)
    grads = gradients(m.g, loss, params)
    art(m, "critic_grad", grads + [loss],
        _tree_io(cfg, "params", True) + [
            tok_bs, mask_bs, ("returns", [b, s], "f32")],
        _tree_io(cfg, "out/grads", True) + [sc("out/loss")])

    m = M(cfg)
    params = m.tree_params(True)
    chosen = m.g.param("s32", [b, s])
    rejected = m.g.param("s32", [b, s])
    cidx = m.g.param("s32", [b])
    ridx = m.g.param("s32", [b])
    s_c = m.reward_score(params, chosen, cidx)
    s_r = m.reward_score(params, rejected, ridx)
    diff = m.g.sub(s_c, s_r)
    nd = m.g.neg(diff)
    # -log sigmoid(diff) = softplus(-diff), stable form
    mx = m.g.max(nd, m.g.full_f32(0.0, [b]))
    e = m.g.exp(m.g.neg(m.g.abs(nd)))
    sp = m.g.add(mx, m.g.log(m.g.add(m.g.full_f32(1.0, [b]), e)))
    loss = m.mean_all(sp)
    acc = m.mean_all(m.g.convert(m.g.compare("GT", s_c, s_r), "f32"))
    grads = gradients(m.g, loss, params)
    art(m, "bt_grad", grads + [loss, acc],
        _tree_io(cfg, "params", True) + [
            ("chosen", [b, s], "i32"), ("rejected", [b, s], "i32"),
            ("chosen_idx", [b], "i32"), ("rejected_idx", [b], "i32")],
        _tree_io(cfg, "out/grads", True) + [sc("out/loss"), sc("out/acc")])

    for name, scalar in (("adam_policy", False), ("adam_scalar", True)):
        m = M(cfg)
        p = m.tree_params(scalar)
        mm = m.tree_params(scalar)
        vv = m.tree_params(scalar)
        gg = m.tree_params(scalar)
        step = m.g.param("f32", [])
        lr = m.g.param("f32", [])
        pn, mn, vn = m.adam(p, mm, vv, gg, step, lr)
        art(m, name, pn + mn + vn,
            _tree_io(cfg, "params", scalar) + _tree_io(cfg, "m", scalar)
            + _tree_io(cfg, "v", scalar) + _tree_io(cfg, "grads", scalar)
            + [sc("step"), sc("lr")],
            _tree_io(cfg, "out/params", scalar) + _tree_io(cfg, "out/m", scalar)
            + _tree_io(cfg, "out/v", scalar))

    m = M(cfg)
    params = m.tree_params(False)
    mm = m.tree_params(False)
    vv = m.tree_params(False)
    tokens = m.g.param("s32", [b, s])
    mask = m.g.param("f32", [b, s])
    adv = m.g.param("f32", [b, s])
    old_lp = m.g.param("f32", [b, s])
    ref_lp = m.g.param("f32", [b, s])
    step = m.g.param("f32", [])
    lr = m.g.param("f32", [])
    clip = m.g.param("f32", [])
    klc = m.g.param("f32", [])
    entc = m.g.param("f32", [])
    logits = m.logits(params, tokens)
    lp = m.token_logprob(logits, tokens)
    loss, kl, ent, cf = m.ppo_loss(logits, lp, old_lp, ref_lp, adv, mask,
                                   clip, klc, entc)
    grads = gradients(m.g, loss, params)
    pn, mn, vn = m.adam(params, mm, vv, grads, step, lr)
    art(m, "train_step", pn + mn + vn + [loss, kl, ent, cf],
        _tree_io(cfg, "params", False) + _tree_io(cfg, "m", False)
        + _tree_io(cfg, "v", False) + [
            tok_bs, mask_bs, ("adv", [b, s], "f32"),
            ("old_logp", [b, s], "f32"), ("ref_logp", [b, s], "f32"),
            sc("step"), sc("lr"), sc("clip_eps"), sc("kl_coef"), sc("ent_coef")],
        _tree_io(cfg, "out/params", False) + _tree_io(cfg, "out/m", False)
        + _tree_io(cfg, "out/v", False) + [
            sc("out/loss"), sc("out/kl"), sc("out/entropy"), sc("out/clipfrac")])

    m = M(cfg)
    hn, dh = cfg.n_heads, cfg.d_head
    q = m.g.param("f32", [b, hn, s, dh])
    k = m.g.param("f32", [b, hn, s, dh])
    vvv = m.g.param("f32", [b, hn, s, dh])
    art(m, "attn_micro", [m.attention(q, k, vvv, ("static", 0))],
        [("q", [b, hn, s, dh], "f32"), ("k", [b, hn, s, dh], "f32"),
         ("v", [b, hn, s, dh], "f32")],
        [("out", [b, hn, s, dh], "f32")])

    arts.append(emit_generate_rollout(cfg))

    return arts


def emit_generate_rollout(cfg: GenConfig):
    """Fused rollout: prefill + while(sample → decode) as ONE artifact.

    Loop state (flattened while operands, 25 entries):
      [0..16] params, 17 pos s32[], 18 rows s32[b,s], 19/20 cache k/v,
      21 logits f32[b,v], 22 rng base u32[], 23 done pred[b], 24 temp f32[].
    The body reuses the same `forward_cached` builder code as the
    `decode_step` artifact, so decode logits are op-for-op identical; the
    Gumbel-max sampler draws `rng-bit-generator` bits keyed by the
    loop-carried base counter (advanced by B·V per step), which is exactly
    the formula the host-side stepwise/scheduler sampler uses.
    """
    b, s, p_len, v = cfg.batch, cfg.max_seq, cfg.prompt_len, cfg.vocab
    cache = [cfg.n_layers, b, cfg.n_heads, s, cfg.d_head]

    # -- body: sample next token from carried logits, then decode ----------
    body_m = M(cfg)
    bg = body_m.g
    bparams = body_m.tree_params(False)
    bpos = bg.param("s32", [])
    brows = bg.param("s32", [b, s])
    bck = bg.param("f32", cache)
    bcv = bg.param("f32", cache)
    blogits = bg.param("f32", [b, v])
    bbase = bg.param("u32", [])
    bdone = bg.param("pred", [b])
    btemp = bg.param("f32", [])

    bits = bg.rng_bits(bbase, [b, v])
    u = body_m.to_unit(bits)
    gum = bg.neg(bg.log(bg.neg(bg.log(u))))
    tb = bg.broadcast(btemp, [], [b, v])
    scores = bg.add(bg.div(blogits, tb), gum)
    k = SAMPLER_TOP_K
    if 0 < k < v:
        srt = bg.sort(blogits, 1)  # descending
        th = bg.reshape(bg.slice(srt, [(0, b), (k - 1, k)]), [b])
        keep = bg.compare("GE", blogits, bg.broadcast(th, [0], [b, v]))
        scores = bg.select(keep, scores, bg.full_f32(float("-inf"), [b, v]))
    mx = bg.reduce_max(scores, [1])
    eq = bg.compare("EQ", scores, bg.broadcast(mx, [0], [b, v]))
    iv = bg.iota("s32", [b, v], 1)
    vb = bg.broadcast(bg.c_s32(v), [], [b, v])
    sampled = bg.reduce_min(bg.select(eq, iv, vb), [1])  # first argmax
    padv = bg.broadcast(bg.c_s32(PAD_ID), [], [b])
    tok = bg.select(bdone, padv, sampled)
    rows2 = bg.dyn_update_slice(brows, bg.reshape(tok, [b, 1]),
                                [bg.c_s32(0), bpos])
    eosb = bg.broadcast(bg.c_s32(EOS_ID), [], [b])
    done2 = bg.or_(bdone, bg.compare("EQ", tok, eosb))
    logits2, ck2, cv2 = body_m.forward_cached(
        bparams, bg.reshape(tok, [b, 1]), (bck, bcv), ("dynamic", bpos))
    base2 = bg.add(bbase, bg.c_u32(b * v))
    pos2 = bg.add(bpos, bg.c_s32(1))
    body_outs = bparams + [pos2, rows2, ck2, cv2, logits2, base2, done2, btemp]

    # -- cond: pos < max_seq AND not all rows done --------------------------
    cond_m = M(cfg)
    cg = cond_m.g
    cond_m.tree_params(False)  # params carried through, unused here
    cpos = cg.param("s32", [])
    cg.param("s32", [b, s])
    cg.param("f32", cache)
    cg.param("f32", cache)
    cg.param("f32", [b, v])
    cg.param("u32", [])
    cdone = cg.param("pred", [b])
    cg.param("f32", [])
    in_range = cg.compare("LT", cpos, cg.c_s32(s))
    ndone = cg.reduce_add(cg.convert(cdone, "f32"), [0])
    not_all = cg.compare("LT", ndone, cg.c_f32(float(b)))
    croot = cg.and_(in_range, not_all)

    # -- entry: prefill, seed the state, loop, project out the rows --------
    m = M(cfg)
    eg = m.g
    eparams = m.tree_params(False)
    prompts = eg.param("s32", [b, p_len])
    seed = eg.param("u32", [])
    temp = eg.param("f32", [])
    logits0, ck0, cv0 = m.forward_cached(eparams, prompts, None, ("static", 0))
    fill = eg.broadcast(eg.c_s32(PAD_ID), [], [b, s - p_len])
    rows0 = eg.concat([prompts, fill], 1)
    base0 = eg.mul(seed, eg.c_u32(0x9E3779B1))
    zb = eg.broadcast(eg.c_s32(0), [], [b])
    ob = eg.broadcast(eg.c_s32(1), [], [b])
    done0 = eg.compare("EQ", zb, ob)  # all-false
    state = eparams + [eg.c_s32(p_len), rows0, ck0, cv0, logits0, base0,
                       done0, temp]
    w = eg.while_(state, cg, croot, bg, body_outs, "gen")
    rows_f = eg.gte(w, NP17 + 1)
    return ("generate_rollout", eg.emit_hlo("generate_rollout", [rows_f]),
            _tree_io(cfg, "params", False) + [
                ("prompts", [b, p_len], "i32"), ("seed", [], "u32"),
                ("temp", [], "f32")],
            [("out", [b, s], "i32")])


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------


def _io_json(specs, key):
    items = []
    for name, shape, dtype in specs:
        dims = ", ".join(str(d) for d in shape)
        items.append(f'{{"{key}": "{name}", "shape": [{dims}], '
                     f'"dtype": "{dtype}"}}')
    return "[\n   " + ",\n   ".join(items) + "\n  ]"


def manifest_json(cfg: GenConfig, arts):
    policy = [(f"p/{p}", d, "f32") for p, d in cfg.tree(False)]
    scalar = [(f"p/{p}", d, "f32") for p, d in cfg.tree(True)]
    entries = []
    for name, text, ins, outs in arts:
        entries.append(
            f' "{name}": {{\n  "file": "{name}.hlo.txt",\n'
            f'  "inputs": {_io_json(ins, "name")},\n'
            f'  "outputs": {_io_json(outs, "name")},\n'
            f'  "hlo_bytes": {len(text)}\n }}')
    config = (f'{{"name": "{cfg.name}", "vocab": {cfg.vocab}, '
              f'"d_model": {cfg.d_model}, "n_layers": {cfg.n_layers}, '
              f'"n_heads": {cfg.n_heads}, "d_ff": {cfg.d_ff}, '
              f'"max_seq": {cfg.max_seq}, "prompt_len": {cfg.prompt_len}, '
              f'"batch": {cfg.batch}, "use_pallas": false}}')
    sampler = (f'{{"top_k": {SAMPLER_TOP_K}, '
               f'"stop_at_eos": {"true" if SAMPLER_STOP_AT_EOS else "false"}}}')
    return ('{\n"format_version": 1,\n'
            '"generator": "python -m compile.fixturegen '
            '(HLO emitter for the pure-Rust interpreter backend)",\n'
            f'"config": {config},\n'
            f'"param_count": {cfg.param_count()},\n'
            f'"scalar_param_count": {cfg.scalar_param_count()},\n'
            f'"sampler": {sampler},\n'
            f'"policy_tree": {_io_json(policy, "path")},\n'
            f'"scalar_tree": {_io_json(scalar, "path")},\n'
            '"artifacts": {\n' + ",\n".join(entries) + "\n}\n}\n")
