"""Reverse-mode autodiff over `hlo_builder.Graph`.

Mirrors what `jax.value_and_grad` does for `python/compile/model.py`, so
the gradient fixture artifacts are derived, not hand-written.  Conventions
(exact for the graphs `modelgen` builds):

* `reduce_max` is stop-grad — it only appears as the softmax / logsumexp
  stabilizer whose gradient contribution cancels analytically;
* `maximum`/`minimum` route gradients to the left operand on ties (GE/LE);
* integer/pred ops (`convert` from non-f32, `compare`, `iota`) terminate
  gradient flow.
"""

from __future__ import annotations


def gradients(g, loss, wrt):
    assert g.dims(loss) == (), "loss must be scalar"
    needed = [False] * len(g.nodes)
    stack = [loss]
    while stack:
        i = stack.pop()
        if needed[i]:
            continue
        needed[i] = True
        stack.extend(g.nodes[i].operands)

    adj = {}

    def acc(node, contrib):
        if node in adj:
            adj[node] = g.add(adj[node], contrib)
        else:
            adj[node] = contrib

    adj[loss] = g.c_f32(1.0)
    limit = loss + 1
    for i in range(limit - 1, -1, -1):
        if not needed[i] or i not in adj:
            continue
        n = g.nodes[i]
        if n.shape.dtype != "f32":
            continue
        grad = adj[i]
        op = n.op
        dims = list(n.shape.dims)
        if op in ("parameter", "constant", "iota"):
            continue
        elif op == "add":
            acc(n.operands[0], grad)
            acc(n.operands[1], grad)
        elif op == "subtract":
            acc(n.operands[0], grad)
            acc(n.operands[1], g.neg(grad))
        elif op == "multiply":
            a, b = n.operands
            acc(a, g.mul(grad, b))
            acc(b, g.mul(grad, a))
        elif op == "divide":
            a, b = n.operands
            da = g.div(grad, b)
            acc(a, da)
            acc(b, g.neg(g.mul(da, i)))  # -g*a/b^2 == -(g/b)*(a/b)
        elif op in ("maximum", "minimum"):
            a, b = n.operands
            p = g.compare("GE" if op == "maximum" else "LE", a, b)
            zeros = g.full_f32(0.0, dims)
            acc(a, g.select(p, grad, zeros))
            acc(b, g.select(p, zeros, grad))
        elif op == "negate":
            acc(n.operands[0], g.neg(grad))
        elif op == "abs":
            a = n.operands[0]
            p = g.compare("GE", a, g.full_f32(0.0, dims))
            acc(a, g.select(p, grad, g.neg(grad)))
        elif op == "exponential":
            acc(n.operands[0], g.mul(grad, i))
        elif op == "log":
            acc(n.operands[0], g.div(grad, n.operands[0]))
        elif op == "tanh":
            y2 = g.mul(i, i)
            one_m = g.sub(g.full_f32(1.0, dims), y2)
            acc(n.operands[0], g.mul(grad, one_m))
        elif op == "rsqrt":
            y3 = g.mul(g.mul(i, i), i)
            acc(n.operands[0], g.mul(grad, g.mul(y3, g.full_f32(-0.5, dims))))
        elif op == "sqrt":
            acc(n.operands[0], g.div(g.mul(grad, g.full_f32(0.5, dims)), i))
        elif op == "select":
            p, a, b = n.operands
            zeros = g.full_f32(0.0, dims)
            acc(a, g.select(p, grad, zeros))
            acc(b, g.select(p, zeros, grad))
        elif op == "convert":
            pass  # int/pred source: no flow
        elif op == "broadcast":
            dm = n.attrs["dims"]
            red = [d for d in range(len(dims)) if d not in dm]
            acc(n.operands[0], g.reduce_add(grad, red))
        elif op == "reshape":
            acc(n.operands[0], g.reshape(grad, list(g.dims(n.operands[0]))))
        elif op == "transpose":
            perm = n.attrs["perm"]
            inv = [0] * len(perm)
            for k, p in enumerate(perm):
                inv[p] = k
            acc(n.operands[0], g.transpose(grad, inv))
        elif op == "slice":
            src = n.operands[0]
            sd = g.dims(src)
            low = [s for s, _ in n.attrs["spec"]]
            high = [d - l for (_, l), d in zip(n.attrs["spec"], sd)]
            acc(src, g.pad_zero(grad, low, high))
        elif op == "concatenate":
            dim = n.attrs["dim"]
            start = 0
            for part in n.operands:
                pd = g.dims(part)
                spec = [(start, start + pd[dim]) if k == dim else (0, d)
                        for k, d in enumerate(g.dims(grad))]
                acc(part, g.slice(grad, spec))
                start += pd[dim]
        elif op == "pad":
            src = n.operands[0]
            spec = [(lo, lo + d) for lo, d in
                    zip(n.attrs["low"], g.dims(src))]
            acc(src, g.slice(grad, spec))
        elif op == "reduce_add":
            src = n.operands[0]
            sd = list(g.dims(src))
            kept = [d for d in range(len(sd)) if d not in n.attrs["dims"]]
            acc(src, g.broadcast(grad, kept, sd))
        elif op == "reduce_max":
            pass  # stop-grad (softmax stabilizer)
        elif op == "dot":
            dl, dr = _dot_vjp(g, grad, n)
            acc(n.operands[0], dl)
            acc(n.operands[1], dr)
        else:
            raise ValueError(f"op {op} is not differentiable (node %v{i})")

    outs = []
    for w in wrt:
        if w in adj:
            outs.append(adj[w])
        else:
            outs.append(g.full_f32(0.0, list(g.dims(w))))
    return outs


def _maybe_transpose(g, a, perm):
    if perm == list(range(len(perm))):
        return a
    return g.transpose(a, perm)


def _dot_vjp(g, grad, n):
    lhs, rhs = n.operands
    lb, rb = n.attrs["lb"], n.attrs["rb"]
    lc, rc = n.attrs["lc"], n.attrs["rc"]
    lrank, rrank = len(g.dims(lhs)), len(g.dims(rhs))
    lhs_free = [d for d in range(lrank) if d not in lb and d not in lc]
    rhs_free = [d for d in range(rrank) if d not in rb and d not in rc]
    nb, nlf, nrf = len(lb), len(lhs_free), len(rhs_free)

    # dLHS = dot(G, RHS): contract G's rhs-free block with RHS free dims.
    dl_raw = g.dot_general(
        grad, rhs,
        list(range(nb)), rb,
        list(range(nb + nlf, nb + nlf + nrf)), rhs_free)
    # raw layout: [batch, lhs_free, rhs_contract (ascending)]
    rcs = sorted(rc)
    perm_l = []
    for j in range(lrank):
        if j in lb:
            perm_l.append(lb.index(j))
        elif j in lhs_free:
            perm_l.append(nb + lhs_free.index(j))
        else:
            r = rc[lc.index(j)]
            perm_l.append(nb + nlf + rcs.index(r))
    dl = _maybe_transpose(g, dl_raw, perm_l)
    assert g.dims(dl) == g.dims(lhs)

    # dRHS = dot(LHS, G): contract LHS free dims with G's lhs-free block.
    dr_raw = g.dot_general(
        lhs, grad,
        lb, list(range(nb)),
        lhs_free, list(range(nb, nb + nlf)))
    # raw layout: [batch (lhs_batch order), lhs_contract (ascending), rhs_free]
    lcs = sorted(lc)
    nlc = len(lcs)
    perm_r = []
    for j in range(rrank):
        if j in rb:
            perm_r.append(rb.index(j))
        elif j in rc:
            l = lc[rc.index(j)]
            perm_r.append(nb + lcs.index(l))
        else:
            perm_r.append(nb + nlc + rhs_free.index(j))
    dr = _maybe_transpose(g, dr_raw, perm_r)
    assert g.dims(dr) == g.dims(rhs)
    return dl, dr
