"""Pure-jnp oracles for the L1 kernel and the L2 losses.

Everything the Pallas kernel and the fused train-step artifacts compute is
re-derived here with plain jax.numpy (no pallas, no custom control flow) so
the pytest suite can assert bit-level agreement-within-tolerance.  These
oracles are the CORE correctness signal of the Python side.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive softmax attention over [B, H, S, D]."""
    B, H, S, D = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask, s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def token_logprob_ref(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """logp[:, t] = log p(tokens[t] | tokens[<t]);  logp[:, 0] = 0.

    logits[b, t] are the model's next-token logits AFTER consuming
    tokens[b, :t+1]; so tokens[b, t] is scored by logits[b, t-1].
    """
    logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # score tokens[:, 1:] with logits[:, :-1]
    scored = jnp.take_along_axis(
        logp_all[:, :-1, :], tokens[:, 1:, None], axis=-1
    )[..., 0]
    zeros = jnp.zeros_like(scored[:, :1])
    return jnp.concatenate([zeros, scored], axis=1)


def entropy_ref(logits: jax.Array) -> jax.Array:
    """Per-position categorical entropy, [B, S]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -(jnp.exp(logp) * logp).sum(-1)


def masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    m = mask.astype(jnp.float32)
    return (x * m).sum() / jnp.maximum(m.sum(), 1.0)


def kl_k3_ref(logp: jax.Array, ref_logp: jax.Array) -> jax.Array:
    """Schulman k3 KL estimator (the GRPO/DAPO standard), per token."""
    log_ratio = ref_logp - logp
    return jnp.exp(log_ratio) - log_ratio - 1.0


def ppo_loss_ref(
    logp: jax.Array,
    old_logp: jax.Array,
    ref_logp: jax.Array,
    adv: jax.Array,
    mask: jax.Array,
    entropy: jax.Array,
    *,
    clip_eps: float,
    kl_coef: float,
    ent_coef: float,
) -> tuple[jax.Array, dict]:
    """Token-level PPO-clip with k3 KL penalty and entropy bonus.

    `adv` is per-token [B, S] (GAE for PPO; broadcast sequence advantage
    for GRPO).  Returns (scalar loss, aux dict).
    """
    ratio = jnp.exp(logp - old_logp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)
    kl = kl_k3_ref(logp, ref_logp)
    loss = (
        masked_mean(pg, mask)
        + kl_coef * masked_mean(kl, mask)
        - ent_coef * masked_mean(entropy, mask)
    )
    clipfrac = masked_mean((jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32), mask)
    aux = {
        "pg_loss": masked_mean(pg, mask),
        "kl": masked_mean(kl, mask),
        "entropy": masked_mean(entropy, mask),
        "clipfrac": clipfrac,
    }
    return loss, aux


def grpo_advantage_ref(rewards: jax.Array, group_size: int) -> jax.Array:
    """Group-relative advantages: (r - mean_group) / (std_group + eps).

    rewards: [B] where B = n_groups * group_size, groups contiguous.
    This oracle mirrors `coordinator/sampling.rs::grpo_advantages` on the
    Rust side (checked numerically by the integration test fixtures).
    """
    g = rewards.reshape(-1, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / (std + 1e-6)).reshape(-1)


def sft_loss_ref(logits: jax.Array, tokens: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked next-token cross-entropy."""
    logp = token_logprob_ref(logits, tokens)
    return -masked_mean(logp, mask)


def bt_loss_ref(score_chosen: jax.Array, score_rejected: jax.Array) -> jax.Array:
    """Bradley-Terry pairwise loss: -log sigmoid(s_c - s_r), mean."""
    return -jax.nn.log_sigmoid(score_chosen - score_rejected).mean()


def gae_ref(
    rewards: jax.Array, values: jax.Array, mask: jax.Array,
    *, gamma: float, lam: float,
) -> tuple[jax.Array, jax.Array]:
    """Generalised advantage estimation over [B, S] token sequences.

    `rewards[b, t]` is the per-token reward (terminal reward placed on the
    last unmasked token by the caller); `values[b, t]` the critic value.
    Mirrors `coordinator/sampling.rs::gae` on the Rust side.
    Returns (advantages, returns) both [B, S].
    """
    B, S = rewards.shape
    m = mask.astype(jnp.float32)

    def step(carry, xs):
        next_adv, next_value = carry
        r, v, mk = xs
        delta = r + gamma * next_value * mk - v
        adv = delta + gamma * lam * next_adv * mk
        return (adv, v), adv

    xs = (rewards[:, ::-1].T, values[:, ::-1].T, m[:, ::-1].T)
    (_, _), advs = jax.lax.scan(
        step, (jnp.zeros(B), jnp.zeros(B)), xs
    )
    adv = advs.T[:, ::-1]
    returns = adv + values
    return adv * m, returns * m


def adam_update_ref(p, m, v, g, step, lr, b1, b2, eps, wd=0.0):
    """Single-tensor AdamW reference (bias-corrected)."""
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1**step)
    vhat = v / (1 - b2**step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v
