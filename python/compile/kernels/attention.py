"""L1 — Pallas head-chunked blocked flash attention.

This is the single-chip, TPU-style re-think of G-Core's distributed
attention (paper §4.5).  The paper all-gathers K/V across context-parallel
ranks and processes **a subset of attention heads at a time**, overlapping
KV communication with attention compute, to make 1M-token contexts
trainable.  On the Pallas/TPU model that becomes:

* the "subset of heads at a time" is a **grid axis over heads** — each grid
  step's working set is one head's (q-tile, kv-tile), so the VMEM footprint
  is independent of both the head count and the sequence length;
* the "all-gathered KV streamed per head" becomes **HBM-resident K/V with
  BlockSpec-scheduled VMEM tiles** — the HBM→VMEM schedule replaces the
  paper's NIC→HBM schedule;
* the "overlap comm with compute" becomes the classic **online-softmax
  accumulation** across kv-tiles (running max / denominator in VMEM
  scratch), which is exactly the structure Mosaic double-buffers.

Causal masking is applied block-wise; kv-tiles strictly above the diagonal
skip their matmuls entirely (``pl.when``), halving the causal FLOPs.

The kernel MUST be lowered with ``interpret=True`` here: the CPU PJRT
plugin cannot execute Mosaic custom-calls.  Numerics are validated against
``ref.attention_ref`` by ``python/tests/test_kernel.py`` (hypothesis sweep
over shapes/dtypes); TPU performance is *estimated* from the VMEM/MXU
arithmetic in ``vmem_footprint_bytes`` / ``mxu_utilization_estimate``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32

NEG_INF = -1.0e30  # finite -inf stand-in: keeps bf16/f32 masking NaN-free


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, block_q: int, block_k: int,
):
    """One (batch, head, q-tile, kv-tile) grid step of online-softmax."""
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ikv * block_k

    # Causal block-level skip: if every kv position in this tile is strictly
    # in the future of every q position, the tile contributes nothing.
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [block_q, block_k]
        if causal:
            # element-level mask for tiles straddling the diagonal
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # tile is live iff its first kv position <= last q position
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ikv == nkv - 1)
    def _finalize():
        # masked-out rows (fully-masked q rows cannot occur under causal
        # self-attention, but guard the denominator anyway)
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _pick_block(seq: int, requested: int) -> int:
    """Largest divisor of `seq` that is <= requested (tiles must tile S)."""
    b = min(requested, seq)
    while seq % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    scale: float | None = None,
) -> jax.Array:
    """Blocked flash attention over ``[B, H, S, D]`` tensors.

    Grid = (B, H, S/block_q, S/block_k); one head-tile pair resident in
    VMEM per step (the G-Core head-chunking discipline).
    """
    B, H, S, D = q.shape
    assert k.shape == (B, H, S, D) and v.shape == (B, H, S, D)
    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=bq, block_k=bk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denominator
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


# ---------------------------------------------------------------------------
# Autodiff: the Pallas kernel owns the forward hot path; the backward pass
# recomputes through the jnp reference (identical math — asserted by tests)
# and takes its VJP.  This is the standard "flash forward, recompute
# backward" memory/compute trade; a dedicated Pallas backward kernel is a
# listed extension in DESIGN.md.
# ---------------------------------------------------------------------------

_VJP_CACHE: dict = {}


def flash_attention_diff(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
):
    """Differentiable flash attention (Pallas fwd, recompute-ref bwd)."""
    key = (causal, block_q, block_k)
    if key not in _VJP_CACHE:
        from . import ref as _ref  # local import: avoid cycle at module load

        @jax.custom_vjp
        def f(q, k, v):
            return flash_attention(
                q, k, v, causal=causal, block_q=block_q, block_k=block_k
            )

        def fwd(q, k, v):
            return f(q, k, v), (q, k, v)

        def bwd(res, g):
            q, k, v = res
            _, vjp = jax.vjp(
                lambda q, k, v: _ref.attention_ref(q, k, v, causal=causal),
                q, k, v,
            )
            return vjp(g)

        f.defvjp(fwd, bwd)
        _VJP_CACHE[key] = f
    return _VJP_CACHE[key](q, k, v)


# ---------------------------------------------------------------------------
# TPU perf estimation (DESIGN.md §8).  interpret=True wallclock is NOT a TPU
# proxy; these closed-form estimates are what EXPERIMENTS.md §Perf reports.
# ---------------------------------------------------------------------------

def vmem_footprint_bytes(
    block_q: int, block_k: int, d_head: int, dtype_bytes: int = 4
) -> int:
    """Resident VMEM bytes for one grid step (tiles + scratch).

    q tile + k tile + v tile + o tile (dtype) and f32 scratch
    (acc[bq,D] + m[bq] + l[bq]); Mosaic double-buffers the input tiles,
    so count those twice.
    """
    tiles = (block_q * d_head) + 2 * (block_k * d_head) + (block_q * d_head)
    double_buffered = tiles * 2 * dtype_bytes
    scratch = (block_q * d_head + 2 * block_q) * 4
    return double_buffered + scratch


def attention_flops(batch: int, heads: int, seq: int, d_head: int, causal: bool) -> int:
    """Useful FLOPs of the attention (2 matmuls, halved if causal)."""
    full = 2 * 2 * batch * heads * seq * seq * d_head
    return full // 2 if causal else full


def mxu_utilization_estimate(
    seq: int, d_head: int, block_q: int, block_k: int, causal: bool = True,
    mxu_tile: int = 128,
) -> float:
    """Fraction of issued MXU tile-FLOPs that are useful.

    Tiles are padded up to the 128x128 systolic array in each matmul dim;
    causal block-skipping removes strictly-above-diagonal tiles.
    """
    nq, nk = seq // block_q, seq // block_k

    def pad(x: int) -> int:
        return mxu_tile * math.ceil(x / mxu_tile)

    # per (q,k) tile pair: s = q@k^T  and  acc += p@v
    issued_pair = pad(block_q) * pad(block_k) * pad(d_head) + pad(block_q) * pad(
        d_head
    ) * pad(block_k)
    useful_pair = block_q * block_k * d_head * 2
    if causal:
        live = sum(
            1
            for iq in range(nq)
            for ik in range(nk)
            if ik * block_k <= iq * block_q + block_q - 1
        )
        # within live diagonal tiles roughly half the elements are masked
        diag = sum(
            1
            for iq in range(nq)
            for ik in range(nk)
            if ik * block_k <= iq * block_q + block_q - 1
            and ik * block_k + block_k - 1 > iq * block_q
        )
        useful = useful_pair * (live - diag) + useful_pair * diag * 0.5
        issued = issued_pair * live
    else:
        useful = useful_pair * nq * nk
        issued = issued_pair * nq * nk
    return useful / issued
