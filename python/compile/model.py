"""L2 — the G-Core model zoo as pure JAX, built once at AOT time.

Implements every network the RLHF workflow needs (paper §2.2): the actor
(policy LM), the reference policy (same artifact, frozen params held by the
Rust side), the critic (scalar-head value model), the Bradley-Terry reward
model (scalar head) and the generative verifier (policy-shaped LM used as a
reward model via generation + regex matching, paper §3.2).

Everything is expressed as pure functions over explicit parameter pytrees so
``aot.py`` can lower each entry point to a standalone HLO module.  The Rust
coordinator never imports Python — it loads the HLO text artifacts and the
JSON manifest and marshals flat parameter lists.

Structure notes (the L2 perf targets from DESIGN.md §8):

* blocks are **stacked** (`[L, ...]` leading axis) and traversed with
  ``lax.scan`` so the lowered HLO stays O(1) in depth;
* the attention hot-spot routes through the L1 Pallas kernel
  (``kernels.attention.flash_attention``) when ``cfg.use_pallas`` — the
  pure-jnp path (``kernels.ref.attention_ref``) computes identical math and
  the pytest suite asserts they agree;
* the fused ``train_step`` (grad + AdamW in one module) exists for the
  single-controller fast path; multi-controller runs use ``policy_grad`` +
  Rust-side gradient all-reduce + ``adam_apply``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.attention import flash_attention_diff
from .kernels import ref

Params = dict[str, Any]

# Sampler parameters compiled into `generate_rollout`.  The manifest
# records them (aot.build_manifest "sampler" block) so the Rust runtime
# can refuse a SamplerConfig that asks for anything else instead of
# silently decoding a differently-distributed rollout.
ROLLOUT_TOP_K = 16
ROLLOUT_STOP_AT_EOS = True


# ===========================================================================
# Initialisation
# ===========================================================================

def init_params(cfg: ModelConfig, seed: jax.Array, *, scalar_head: bool) -> Params:
    """GPT-2-style init: N(0, 0.02), residual projections scaled by depth."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    d, f, l, v, s = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab, cfg.max_seq
    ks = jax.random.split(key, 10)
    std = 0.02
    res_std = std / jnp.sqrt(2.0 * l)

    def n(k, shape, sd=std):
        return (jax.random.normal(k, shape) * sd).astype(jnp.float32)

    head_dim = 1 if scalar_head else v
    return {
        "tok_emb": n(ks[0], (v, d)),
        "pos_emb": n(ks[1], (s, d), 0.01),
        "blk": {
            "ln1_g": jnp.ones((l, d)),
            "ln1_b": jnp.zeros((l, d)),
            "wq": n(ks[2], (l, d, d)),
            "wk": n(ks[3], (l, d, d)),
            "wv": n(ks[4], (l, d, d)),
            "wo": n(ks[5], (l, d, d), res_std),
            "ln2_g": jnp.ones((l, d)),
            "ln2_b": jnp.zeros((l, d)),
            "w1": n(ks[6], (l, d, f)),
            "b1": jnp.zeros((l, f)),
            "w2": n(ks[7], (l, f, d), res_std),
            "b2": jnp.zeros((l, d)),
        },
        "lnf_g": jnp.ones((d,)),
        "lnf_b": jnp.zeros((d,)),
        "head": n(ks[8], (d, head_dim)),
    }


def zeros_like_params(params: Params) -> Params:
    return jax.tree.map(jnp.zeros_like, params)


# ===========================================================================
# Transformer forward
# ===========================================================================

def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads):  # [B,S,D] -> [B,H,S,Dh]
    B, S, D = x.shape
    return x.reshape(B, S, n_heads, D // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):  # [B,H,S,Dh] -> [B,S,D]
    B, H, S, Dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)


def _block(cfg: ModelConfig, h: jax.Array, p: Params) -> jax.Array:
    """One pre-LN transformer block over [B, S, D] (full causal)."""
    x = _layernorm(h, p["ln1_g"], p["ln1_b"])
    q = _split_heads(x @ p["wq"], cfg.n_heads)
    k = _split_heads(x @ p["wk"], cfg.n_heads)
    v = _split_heads(x @ p["wv"], cfg.n_heads)
    if cfg.use_pallas:
        attn = flash_attention_diff(
            q, k, v, causal=True, block_q=cfg.block_q, block_k=cfg.block_k
        )
    else:
        attn = ref.attention_ref(q, k, v, causal=True)
    h = h + _merge_heads(attn) @ p["wo"]
    x = _layernorm(h, p["ln2_g"], p["ln2_b"])
    x = jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return h + x


def trunk(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """Embed + L blocks + final LN.  tokens [B, S] -> hidden [B, S, D]."""
    B, S = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][:S][None]

    def body(h, blk_p):
        return _block(cfg, h, blk_p), None

    h, _ = jax.lax.scan(body, h, params["blk"])
    return _layernorm(h, params["lnf_g"], params["lnf_b"])


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    return trunk(cfg, params, tokens) @ params["head"]


def values_fn(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """Scalar-head model: per-token value/score [B, S]."""
    return (trunk(cfg, params, tokens) @ params["head"])[..., 0]


# ===========================================================================
# KV-cached generation (prefill + decode_step)
# ===========================================================================
# The generation engine the L3 coordinator schedules.  Cache layout:
#   cache_k, cache_v: [L, B, H, Smax, Dh]
# Cached attention runs on the jnp path (rectangular, position-masked);
# the Pallas kernel owns the square causal training forward.

def _cached_block(cfg, h, blk_p, ck, cv, start_pos):
    """Block forward for T new tokens at positions [start, start+T).

    h: [B, T, D]; ck/cv: [B, H, Smax, Dh] (this layer's cache).
    Returns (h', ck', cv').
    """
    B, T, D = h.shape
    Smax = ck.shape[2]
    x = _layernorm(h, blk_p["ln1_g"], blk_p["ln1_b"])
    q = _split_heads(x @ blk_p["wq"], cfg.n_heads)   # [B,H,T,Dh]
    k = _split_heads(x @ blk_p["wk"], cfg.n_heads)
    v = _split_heads(x @ blk_p["wv"], cfg.n_heads)
    ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, start_pos, 0))
    cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, start_pos, 0))
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
    s = jnp.einsum("bhtd,bhkd->bhtk", q, ck) * scale  # [B,H,T,Smax]
    kpos = jnp.arange(Smax)[None, None, None, :]
    qpos = (start_pos + jnp.arange(T))[None, None, :, None]
    s = jnp.where(kpos <= qpos, s, -1.0e30)
    attn = jax.nn.softmax(s, axis=-1) @ cv            # [B,H,T,Dh]
    h = h + _merge_heads(attn) @ blk_p["wo"]
    x = _layernorm(h, blk_p["ln2_g"], blk_p["ln2_b"])
    x = jax.nn.gelu(x @ blk_p["w1"] + blk_p["b1"]) @ blk_p["w2"] + blk_p["b2"]
    return h + x, ck, cv


def forward_cached(cfg, params, tokens, cache_k, cache_v, start_pos):
    """tokens [B,T] at positions [start, start+T) -> (last logits, caches)."""
    B, T = tokens.shape
    pos_emb = jax.lax.dynamic_slice(
        params["pos_emb"], (start_pos, 0), (T, cfg.d_model)
    )
    h = params["tok_emb"][tokens] + pos_emb[None]

    def body(h, xs):
        blk_p, ck, cv = xs
        h, ck, cv = _cached_block(cfg, h, blk_p, ck, cv, start_pos)
        return h, (ck, cv)

    h, (cache_k, cache_v) = jax.lax.scan(
        body, h, (params["blk"], cache_k, cache_v)
    )
    h = _layernorm(h[:, -1], params["lnf_g"], params["lnf_b"])
    return h @ params["head"], cache_k, cache_v


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array):
    """Consume the [B, P] prompt; return (last logits [B,V], caches)."""
    B = tokens.shape[0]
    shape = (cfg.n_layers, B, cfg.n_heads, cfg.max_seq, cfg.d_head)
    ck = jnp.zeros(shape, jnp.float32)
    cv = jnp.zeros(shape, jnp.float32)
    return forward_cached(cfg, params, tokens, ck, cv, 0)


def decode_step(cfg, params, cache_k, cache_v, token, pos):
    """One autoregressive step: token [B] at scalar position `pos`."""
    return forward_cached(cfg, params, token[:, None], cache_k, cache_v, pos)


def generate_rollout(cfg: ModelConfig, params: Params, prompts: jax.Array,
                     seed: jax.Array, temperature: jax.Array) -> jax.Array:
    """Whole-rollout generation fused into ONE module: prefill + scan over
    decode steps with in-graph top-k temperature sampling.

    This is the generation-engine hot path (§Perf, EXPERIMENTS.md): the
    per-token artifact (`decode_step`) costs a host↔device round-trip of
    the full KV cache per token; here the cache never leaves the device.
    The L3 coordinator passes sampling params (seed, temperature) like a
    client calling vLLM; top-k is baked from the config.

    prompts: [B, P] int32; returns rows [B, S] (prompt + generated; PAD
    after each row's EOS, matching the Rust sampler's contract).
    """
    B = prompts.shape[0]
    P, S, V = cfg.prompt_len, cfg.max_seq, cfg.vocab
    EOS, PAD = 10, 0
    top_k = ROLLOUT_TOP_K  # recorded in the manifest's sampler block

    logits, ck, cv = forward_cached(
        cfg, params,
        prompts,
        jnp.zeros((cfg.n_layers, B, cfg.n_heads, S, cfg.d_head), jnp.float32),
        jnp.zeros((cfg.n_layers, B, cfg.n_heads, S, cfg.d_head), jnp.float32),
        0,
    )
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    temp = jnp.maximum(temperature, 1e-4)

    def sample(logits, key):
        # top-k mask then temperature categorical.  NB: use sort, not
        # lax.top_k — the xla_extension 0.5.1 HLO-text parser rejects the
        # TopK op's `largest` attribute.
        kth = jnp.sort(logits, axis=-1)[:, V - top_k][:, None]
        masked = jnp.where(logits >= kth, logits, -1e30)
        return jax.random.categorical(key, masked / temp, axis=-1)

    def step(carry, xs):
        logits, ck, cv, done = carry
        pos, key = xs
        tok = sample(logits, key)
        tok = jnp.where(done, PAD, tok).astype(jnp.int32)
        done = done | (tok == EOS)
        logits, ck, cv = forward_cached(cfg, params, tok[:, None], ck, cv, pos)
        return (logits, ck, cv, done), tok

    positions = jnp.arange(P, S)
    keys = jax.random.split(key, S - P)
    (_, _, _, _), toks = jax.lax.scan(
        step, (logits, ck, cv, jnp.zeros(B, bool)), (positions, keys)
    )
    return jnp.concatenate([prompts, toks.T], axis=1)


# ===========================================================================
# Losses / gradients
# ===========================================================================

def logprob_fn(cfg, params, tokens):
    return ref.token_logprob_ref(logits_fn(cfg, params, tokens), tokens)


def policy_loss(
    cfg, params, tokens, mask, adv, old_logp, ref_logp, clip_eps, kl_coef, ent_coef
):
    logits = logits_fn(cfg, params, tokens)
    logp = ref.token_logprob_ref(logits, tokens)
    entropy = ref.entropy_ref(logits)
    loss, aux = ref.ppo_loss_ref(
        logp, old_logp, ref_logp, adv, mask, entropy,
        clip_eps=clip_eps, kl_coef=kl_coef, ent_coef=ent_coef,
    )
    return loss, aux


def policy_grad(cfg, params, tokens, mask, adv, old_logp, ref_logp,
                clip_eps, kl_coef, ent_coef):
    """Grad of the clipped policy objective.  Serves PPO and GRPO:
    for GRPO the L3 coordinator broadcasts the group-relative sequence
    advantage across tokens before the call."""
    (loss, aux), grads = jax.value_and_grad(
        lambda p: policy_loss(
            cfg, p, tokens, mask, adv, old_logp, ref_logp,
            clip_eps, kl_coef, ent_coef,
        ),
        has_aux=True,
    )(params)
    return grads, loss, aux["kl"], aux["entropy"], aux["clipfrac"]


def sft_grad(cfg, params, tokens, mask):
    """Supervised next-token cross-entropy (verifier / policy warm-start)."""
    def loss_fn(p):
        return ref.sft_loss_ref(logits_fn(cfg, p, tokens), tokens, mask)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return grads, loss


def critic_grad(cfg, params, tokens, mask, returns):
    """Masked MSE between critic values and returns."""
    def loss_fn(p):
        v = values_fn(cfg, p, tokens)
        return ref.masked_mean((v - returns) ** 2, mask)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return grads, loss


def reward_score(cfg, params, tokens, last_idx):
    """BT reward: value at the final real token of each sequence, [B]."""
    v = values_fn(cfg, params, tokens)
    return jnp.take_along_axis(v, last_idx[:, None], axis=1)[:, 0]


def bt_grad(cfg, params, chosen, rejected, c_idx, r_idx):
    """Bradley-Terry pairwise grad: -log sigmoid(s_chosen - s_rejected)."""
    def loss_fn(p):
        sc = reward_score(cfg, p, chosen, c_idx)
        sr = reward_score(cfg, p, rejected, r_idx)
        return ref.bt_loss_ref(sc, sr), (sc > sr).astype(jnp.float32).mean()

    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return grads, loss, acc


# ===========================================================================
# Optimiser
# ===========================================================================

def adam_apply(cfg: ModelConfig, params, m, v, grads, step, lr):
    """Fused AdamW over the whole tree (betas/eps/wd baked from cfg)."""
    b1, b2, eps, wd = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.weight_decay
    c1 = 1.0 - b1**step
    c2 = 1.0 - b2**step

    def upd(p, mm, vv, g):
        mm = b1 * mm + (1 - b1) * g
        vv = b2 * vv + (1 - b2) * g * g
        p = p - lr * ((mm / c1) / (jnp.sqrt(vv / c2) + eps) + wd * p)
        return p, mm, vv

    flat_p, tdef = jax.tree.flatten(params)
    flat_m = jax.tree.leaves(m)
    flat_v = jax.tree.leaves(v)
    flat_g = jax.tree.leaves(grads)
    out = [upd(*t) for t in zip(flat_p, flat_m, flat_v, flat_g)]
    params = jax.tree.unflatten(tdef, [o[0] for o in out])
    m = jax.tree.unflatten(tdef, [o[1] for o in out])
    v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return params, m, v


def train_step(cfg, params, m, v, tokens, mask, adv, old_logp, ref_logp,
               step, lr, clip_eps, kl_coef, ent_coef):
    """Fused grad+AdamW — the single-controller (dp=1) fast path."""
    grads, loss, kl, entropy, clipfrac = policy_grad(
        cfg, params, tokens, mask, adv, old_logp, ref_logp,
        clip_eps, kl_coef, ent_coef,
    )
    params, m, v = adam_apply(cfg, params, m, v, grads, step, lr)
    return params, m, v, loss, kl, entropy, clipfrac
