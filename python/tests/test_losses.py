"""Loss-oracle unit tests: PPO/GRPO/GAE/BT math on hand-checkable cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def test_masked_mean_ignores_masked():
    x = jnp.array([[1.0, 2.0, 100.0]])
    m = jnp.array([[1.0, 1.0, 0.0]])
    assert float(ref.masked_mean(x, m)) == pytest.approx(1.5)


def test_masked_mean_empty_mask_is_zero():
    x = jnp.ones((2, 3))
    m = jnp.zeros((2, 3))
    assert float(ref.masked_mean(x, m)) == 0.0


def test_kl_k3_properties():
    lp = jnp.array([-1.0, -2.0, -0.5])
    # identical distributions -> 0
    np.testing.assert_allclose(ref.kl_k3_ref(lp, lp), 0.0, atol=1e-7)
    # k3 estimator is non-negative for any log-ratio
    rlp = jnp.array([-1.5, -1.0, -3.0])
    assert bool((ref.kl_k3_ref(lp, rlp) >= 0).all())


def test_ppo_clip_blocks_large_ratio_gain():
    """Once ratio > 1+eps with positive advantage, the objective must stop
    improving (the clipped branch wins)."""
    old = jnp.array([[-1.0]])
    adv = jnp.array([[1.0]])
    mask = jnp.ones((1, 1))
    ent = jnp.zeros((1, 1))

    def pg(new_lp):
        loss, _ = ref.ppo_loss_ref(
            jnp.array([[new_lp]]), old, old, adv, mask, ent,
            clip_eps=0.2, kl_coef=0.0, ent_coef=0.0,
        )
        return float(loss)

    # inside the clip: improving logprob reduces the loss
    assert pg(-0.95) < pg(-1.0)
    # outside the clip: loss is flat at -(1+eps)*adv
    assert pg(-0.5) == pytest.approx(pg(-0.2), abs=1e-6)
    assert pg(-0.5) == pytest.approx(-1.2, abs=1e-6)


def test_ppo_clipfrac_counts_clipped_tokens():
    old = jnp.zeros((1, 4))
    new = jnp.array([[0.0, 0.5, -0.5, 0.05]])  # ratios 1, 1.65, 0.61, 1.05
    mask = jnp.ones((1, 4))
    _, aux = ref.ppo_loss_ref(
        new, old, old, jnp.ones((1, 4)), mask, jnp.zeros((1, 4)),
        clip_eps=0.2, kl_coef=0.0, ent_coef=0.0,
    )
    assert float(aux["clipfrac"]) == pytest.approx(0.5)


def test_grpo_advantage_zero_mean_unit_std():
    r = jnp.array([1.0, 2.0, 3.0, 4.0, 10.0, 10.0, 10.0, 14.0])
    adv = ref.grpo_advantage_ref(r, group_size=4)
    g = np.asarray(adv).reshape(2, 4)
    np.testing.assert_allclose(g.mean(axis=1), 0.0, atol=1e-6)
    np.testing.assert_allclose(g.std(axis=1), 1.0, atol=1e-3)


def test_grpo_advantage_constant_group_is_zero():
    """All-same rewards (the DAPO filter case) give ~zero advantage."""
    r = jnp.array([5.0, 5.0, 5.0, 5.0])
    adv = ref.grpo_advantage_ref(r, group_size=4)
    np.testing.assert_allclose(adv, 0.0, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n_groups=st.integers(1, 4),
    gsize=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_grpo_advantage_hypothesis(n_groups, gsize, seed):
    r = jax.random.normal(jax.random.PRNGKey(seed), (n_groups * gsize,)) * 3
    adv = ref.grpo_advantage_ref(r, gsize)
    g = np.asarray(adv).reshape(n_groups, gsize)
    np.testing.assert_allclose(g.mean(axis=1), 0.0, atol=1e-5)


def test_gae_terminal_only_reward():
    """Single terminal reward, zero values: adv[t] = (gamma*lam)^(T-1-t) * r."""
    B, S = 1, 5
    gamma, lam = 0.9, 0.8
    rewards = jnp.zeros((B, S)).at[0, S - 1].set(1.0)
    values = jnp.zeros((B, S))
    mask = jnp.ones((B, S))
    adv, ret = ref.gae_ref(rewards, values, mask, gamma=gamma, lam=lam)
    expected = [(gamma * lam) ** (S - 1 - t) for t in range(S)]
    np.testing.assert_allclose(adv[0], expected, rtol=1e-5)
    np.testing.assert_allclose(ret, adv, rtol=1e-6)  # values are zero


def test_gae_perfect_critic_zero_advantage():
    """If values exactly equal discounted returns, advantages vanish."""
    B, S = 1, 4
    gamma, lam = 1.0, 1.0
    rewards = jnp.array([[0.0, 0.0, 0.0, 2.0]])
    values = jnp.array([[2.0, 2.0, 2.0, 2.0]])  # true return-to-go
    mask = jnp.ones((B, S))
    adv, _ = ref.gae_ref(rewards, values, mask, gamma=gamma, lam=lam)
    np.testing.assert_allclose(adv, 0.0, atol=1e-6)


def test_bt_loss_ordering():
    lo = ref.bt_loss_ref(jnp.array([2.0]), jnp.array([0.0]))
    hi = ref.bt_loss_ref(jnp.array([0.0]), jnp.array([2.0]))
    eq = ref.bt_loss_ref(jnp.array([1.0]), jnp.array([1.0]))
    assert float(lo) < float(eq) < float(hi)
    assert float(eq) == pytest.approx(np.log(2.0), rel=1e-5)


def test_sft_loss_uniform_model():
    """Uniform logits -> loss == log(V)."""
    B, S, V = 2, 8, 256
    logits = jnp.zeros((B, S, V))
    tokens = jnp.zeros((B, S), jnp.int32)
    mask = jnp.ones((B, S)).at[:, 0].set(0.0)  # position 0 is never scored
    loss = ref.sft_loss_ref(logits, tokens, mask)
    assert float(loss) == pytest.approx(np.log(V), rel=1e-5)


def test_entropy_uniform_and_peaked():
    V = 256
    uni = ref.entropy_ref(jnp.zeros((1, 1, V)))
    assert float(uni[0, 0]) == pytest.approx(np.log(V), rel=1e-5)
    peak = ref.entropy_ref(jnp.zeros((1, 1, V)).at[0, 0, 0].set(100.0))
    assert float(peak[0, 0]) < 1e-3
