"""AOT pipeline: manifest structure, flatten-order stability, HLO sanity."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.config import PRESETS

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def entry_points():
    return aot.build_entry_points(CFG)


def test_all_expected_artifacts_present(entry_points):
    expected = {
        "init_policy", "init_scalar", "fwd_logits", "logprob", "prefill",
        "decode_step", "generate_rollout", "value_score", "reward_score",
        "policy_grad", "sft_grad", "critic_grad", "bt_grad", "adam_policy",
        "adam_scalar", "train_step", "attn_micro",
    }
    assert set(entry_points) == expected


def test_flatten_order_is_sorted_dict_keys():
    """The Rust side indexes params by manifest order; jax flattens dicts in
    sorted-key order — pin that contract."""
    params = jax.eval_shape(
        lambda s: model.init_params(CFG, s, scalar_head=False),
        jax.ShapeDtypeStruct((), jnp.uint32),
    )
    names = [n for n, _ in aot._flatten_with_names(params, "p")]
    assert names[0] == "p/blk/b1"  # 'blk' < 'head' < 'lnf_g' ... sorted
    assert names == sorted(names)
    assert len(names) == 17  # 12 block tensors + 5 top-level


def test_policy_tree_shapes_cover_param_count():
    params = jax.eval_shape(
        lambda s: model.init_params(CFG, s, scalar_head=False),
        jax.ShapeDtypeStruct((), jnp.uint32),
    )
    total = 0
    for _, leaf in aot._flatten_with_names(params, "p"):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    assert total == CFG.param_count()


def test_manifest_against_built_artifacts():
    """If `make artifacts` has run, the manifest on disk must agree with a
    fresh in-process build (guards against stale artifacts)."""
    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "tiny",
        "manifest.json",
    )
    if not os.path.exists(path):
        pytest.skip("artifacts/tiny not built")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["param_count"] == CFG.param_count()
    assert manifest["scalar_param_count"] == CFG.scalar_param_count()
    eps = aot.build_entry_points(CFG)
    assert set(manifest["artifacts"]) == set(eps)
    # input arity contract: params leaves + data args
    pg = manifest["artifacts"]["policy_grad"]
    assert len(pg["inputs"]) == 17 + 8
    ts = manifest["artifacts"]["train_step"]
    assert len(ts["inputs"]) == 17 * 3 + 10
    # every input/output spec carries shape + dtype
    for art in manifest["artifacts"].values():
        for io in art["inputs"] + art["outputs"]:
            assert "shape" in io and io["dtype"] in {"f32", "i32", "u32", "bf16"}


def test_hlo_text_lowering_smoke():
    """Lower the cheapest artifact and sanity-check the HLO text format the
    Rust loader consumes (ENTRY + parameters, no serialized-proto path)."""
    fn, args, _ = aot.build_entry_points(CFG)["attn_micro"]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text
    # the ENTRY computation takes exactly q, k, v
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == 3


def test_decode_step_io_roundtrip_shapes(entry_points):
    """decode_step outputs (logits, caches) shaped exactly like its cache
    inputs — the L3 generation loop feeds outputs back as inputs."""
    fn, args, names = entry_points["decode_step"]
    out = jax.eval_shape(fn, *args)
    logits, ck, cv = out
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert ck.shape == args[1].shape and cv.shape == args[2].shape
