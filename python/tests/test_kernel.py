"""L1 correctness: Pallas flash attention vs the pure-jnp oracle.

The hypothesis sweep is the CORE kernel signal: shapes x dtypes x block
sizes x causal flags, asserting allclose against ``ref.attention_ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention,
    flash_attention_diff,
    vmem_footprint_bytes,
    mxu_utilization_estimate,
    attention_flops,
)
from compile.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape)
    return x.astype(dtype)


def _tol(dtype):
    return {"atol": 2e-2, "rtol": 2e-2} if dtype == jnp.bfloat16 else {
        "atol": 2e-5, "rtol": 2e-5}


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_basic(causal, dtype):
    B, H, S, D = 2, 3, 64, 16
    q, k, v = (_rand(i, (B, H, S, D), dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    expected = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expected.astype(jnp.float32), **_tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_flash_matches_ref_hypothesis(b, h, s_blocks, d, bq, bk, causal, dtype, seed):
    s = max(bq, bk) * s_blocks
    q = _rand(seed, (b, h, s, d), dtype)
    k = _rand(seed + 1, (b, h, s, d), dtype)
    v = _rand(seed + 2, (b, h, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    expected = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expected.astype(jnp.float32), **_tol(dtype)
    )


def test_block_size_not_dividing_seq():
    """_pick_block must fall back to a divisor of S."""
    B, H, S, D = 1, 2, 48, 16  # 48 not divisible by default 32
    q, k, v = (_rand(i, (B, H, S, D), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


def test_scale_override():
    B, H, S, D = 1, 1, 32, 8
    q, k, v = (_rand(i, (B, H, S, D), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8, scale=0.5)
    expected = ref.attention_ref(q, k, v, causal=True, scale=0.5)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_gradients_match_ref(causal):
    """VJP of the Pallas path (recompute-ref backward) == autodiff of ref."""
    B, H, S, D = 2, 2, 32, 16
    q, k, v = (_rand(i, (B, H, S, D), jnp.float32) for i in range(3))
    w = jnp.sin(jnp.arange(B * H * S * D, dtype=jnp.float32)).reshape(B, H, S, D)

    def loss_pallas(q, k, v):
        o = flash_attention_diff(q, k, v, causal=causal, block_q=16, block_k=16)
        return (o * w).sum()

    def loss_ref(q, k, v):
        o = ref.attention_ref(q, k, v, causal=causal)
        return (o * w).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_numerical_stability_large_logits():
    """Online softmax must survive logits far outside exp() range."""
    B, H, S, D = 1, 1, 32, 16
    q = _rand(0, (B, H, S, D), jnp.float32) * 100.0
    k = _rand(1, (B, H, S, D), jnp.float32) * 100.0
    v = _rand(2, (B, H, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    assert bool(jnp.isfinite(out).all())
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=1e-4, rtol=1e-4)


def test_single_kv_block_degenerate():
    """block_k == S: init and finalize land on the same grid step."""
    B, H, S, D = 1, 1, 16, 8
    q, k, v = (_rand(i, (B, H, S, D), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    expected = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=2e-5, rtol=2e-5)


# -- perf-estimate arithmetic (DESIGN.md §8) --------------------------------

def test_vmem_footprint_within_budget():
    # the e2e100m config tiles must sit far below a 16 MB VMEM budget
    assert vmem_footprint_bytes(64, 64, 64) < 16 * 2**20
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20


def test_mxu_utilization_bounds():
    u = mxu_utilization_estimate(1024, 128, 128, 128, causal=True)
    assert 0.0 < u <= 1.0
    u_nc = mxu_utilization_estimate(1024, 128, 128, 128, causal=False)
    assert 0.0 < u_nc <= 1.0


def test_attention_flops_causal_half():
    full = attention_flops(2, 4, 256, 64, causal=False)
    half = attention_flops(2, 4, 256, 64, causal=True)
    assert half * 2 == full
