"""L2 correctness: model forward/generation/optimiser invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import PRESETS, ModelConfig
from compile.kernels import ref

CFG = PRESETS["tiny"]
CFG_JNP = dataclasses.replace(CFG, use_pallas=False)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jnp.uint32(0), scalar_head=False)


@pytest.fixture(scope="module")
def sparams():
    return model.init_params(CFG, jnp.uint32(1), scalar_head=True)


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(7), (CFG.batch, CFG.max_seq), 0, CFG.vocab
    )


def test_param_count_matches_config(params, sparams):
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == CFG.param_count()
    ns = sum(x.size for x in jax.tree.leaves(sparams))
    assert ns == CFG.scalar_param_count()


def test_pallas_and_jnp_paths_agree(params, tokens):
    """cfg.use_pallas must be a pure implementation detail."""
    lo_p = model.logits_fn(CFG, params, tokens)
    lo_j = model.logits_fn(CFG_JNP, params, tokens)
    np.testing.assert_allclose(lo_p, lo_j, atol=3e-4, rtol=3e-4)


def test_logits_shape_and_finite(params, tokens):
    logits = model.logits_fn(CFG_JNP, params, tokens)
    assert logits.shape == (CFG.batch, CFG.max_seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_logprob_consistency(params, tokens):
    """logprob artifact == log_softmax(logits) gathered at next tokens."""
    lp = model.logprob_fn(CFG_JNP, params, tokens)
    logits = model.logits_fn(CFG_JNP, params, tokens)
    expected = ref.token_logprob_ref(logits, tokens)
    np.testing.assert_allclose(lp, expected, atol=1e-6)
    assert bool((lp <= 1e-6).all())  # log-probabilities
    np.testing.assert_allclose(lp[:, 0], 0.0)


def test_causality(params):
    """Changing token t must not affect logits at positions < t."""
    t1 = jax.random.randint(jax.random.PRNGKey(0), (1, CFG.max_seq), 0, 256)
    t2 = t1.at[0, CFG.max_seq // 2].set((t1[0, CFG.max_seq // 2] + 1) % 256)
    l1 = model.logits_fn(CFG_JNP, params, t1)
    l2 = model.logits_fn(CFG_JNP, params, t2)
    cut = CFG.max_seq // 2
    np.testing.assert_allclose(l1[0, :cut], l2[0, :cut], atol=1e-6)
    # and MUST affect the position itself
    assert float(jnp.abs(l1[0, cut] - l2[0, cut]).max()) > 1e-6


def test_prefill_decode_matches_full_forward(params, tokens):
    """KV-cached generation path == full forward — the generation-engine
    correctness contract the L3 sampler depends on."""
    B, P, S = CFG.batch, CFG.prompt_len, CFG.max_seq
    logits_full = model.logits_fn(CFG_JNP, params, tokens)

    last, ck, cv = model.prefill(CFG_JNP, params, tokens[:, :P])
    np.testing.assert_allclose(last, logits_full[:, P - 1], atol=1e-4, rtol=1e-4)

    for pos in range(P, min(P + 4, S)):
        last, ck, cv = model.decode_step(
            CFG_JNP, params, ck, cv, tokens[:, pos], pos
        )
        np.testing.assert_allclose(
            last, logits_full[:, pos], atol=1e-4, rtol=1e-4
        )


def test_value_and_reward_score(sparams, tokens):
    v = model.values_fn(CFG_JNP, sparams, tokens)
    assert v.shape == (CFG.batch, CFG.max_seq)
    idx = jnp.full((CFG.batch,), CFG.max_seq - 3, jnp.int32)
    s = model.reward_score(CFG_JNP, sparams, tokens, idx)
    np.testing.assert_allclose(s, v[:, CFG.max_seq - 3], atol=1e-6)


def test_adam_apply_matches_reference(params):
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    m = model.zeros_like_params(params)
    v = model.zeros_like_params(params)
    p1, m1, v1 = model.adam_apply(
        CFG, params, m, v, grads, jnp.float32(1.0), jnp.float32(1e-3)
    )
    # check one leaf against the single-tensor oracle
    p_ref, m_ref, v_ref = ref.adam_update_ref(
        params["head"], m["head"], v["head"], grads["head"],
        1.0, 1e-3, CFG.adam_b1, CFG.adam_b2, CFG.adam_eps,
    )
    np.testing.assert_allclose(p1["head"], p_ref, atol=1e-6)
    np.testing.assert_allclose(m1["head"], m_ref, atol=1e-7)
    np.testing.assert_allclose(v1["head"], v_ref, atol=1e-9)


def test_sft_training_reduces_loss(params, tokens):
    """A few SFT steps on a fixed batch must reduce the loss."""
    mask = jnp.ones((CFG.batch, CFG.max_seq))
    p = params
    m = model.zeros_like_params(p)
    v = model.zeros_like_params(p)
    losses = []
    for step in range(1, 6):
        grads, loss = model.sft_grad(CFG_JNP, p, tokens, mask)
        p, m, v = model.adam_apply(
            CFG, p, m, v, grads, jnp.float32(step), jnp.float32(3e-3)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_policy_grad_zero_advantage_keeps_policy(params, tokens):
    """With adv == 0 and matching ref, the pg+kl gradient must vanish
    (entropy term disabled)."""
    mask = jnp.ones((CFG.batch, CFG.max_seq))
    lp = model.logprob_fn(CFG_JNP, params, tokens)
    grads, loss, kl, ent, cf = model.policy_grad(
        CFG_JNP, params, tokens, mask, jnp.zeros_like(lp), lp, lp,
        jnp.float32(0.2), jnp.float32(0.1), jnp.float32(0.0),
    )
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm < 1e-3, gnorm
    assert float(kl) == pytest.approx(0.0, abs=1e-6)
    assert float(cf) == pytest.approx(0.0, abs=1e-6)


def test_policy_grad_moves_probability_toward_positive_adv(params, tokens):
    """One policy step with +adv on a batch must raise its logprob."""
    mask = jnp.ones((CFG.batch, CFG.max_seq))
    lp0 = model.logprob_fn(CFG_JNP, params, tokens)
    adv = jnp.ones_like(lp0)
    m = model.zeros_like_params(params)
    v = model.zeros_like_params(params)
    p, m, v, loss, kl, ent, cf = model.train_step(
        CFG_JNP, params, m, v, tokens, mask, adv, lp0, lp0,
        jnp.float32(1.0), jnp.float32(1e-3),
        jnp.float32(0.2), jnp.float32(0.0), jnp.float32(0.0),
    )
    lp1 = model.logprob_fn(CFG_JNP, p, tokens)
    assert float((lp1 - lp0).sum()) > 0.0


def test_bt_grad_improves_pairwise_accuracy(sparams, tokens):
    """BT reward training must fit a fixed preference batch."""
    B, S = CFG.batch, CFG.max_seq
    chosen = tokens
    rejected = jnp.flip(tokens, axis=1)
    idx = jnp.full((B,), S - 1, jnp.int32)
    p = sparams
    m = model.zeros_like_params(p)
    v = model.zeros_like_params(p)
    first_loss = None
    for step in range(1, 16):
        grads, loss, acc = model.bt_grad(CFG_JNP, p, chosen, rejected, idx, idx)
        if first_loss is None:
            first_loss = float(loss)
        p, m, v = model.adam_apply(
            CFG, p, m, v, grads, jnp.float32(step), jnp.float32(3e-3)
        )
    assert float(loss) < first_loss
    assert float(acc) == 1.0


def test_critic_grad_fits_returns(sparams, tokens):
    mask = jnp.ones((CFG.batch, CFG.max_seq))
    returns = jnp.linspace(0, 1, CFG.max_seq)[None].repeat(CFG.batch, 0)
    p = sparams
    m = model.zeros_like_params(p)
    v = model.zeros_like_params(p)
    losses = []
    for step in range(1, 11):
        grads, loss = model.critic_grad(CFG_JNP, p, tokens, mask, returns)
        p, m, v = model.adam_apply(
            CFG, p, m, v, grads, jnp.float32(step), jnp.float32(3e-3)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9


def test_init_deterministic():
    p1 = model.init_params(CFG, jnp.uint32(42), scalar_head=False)
    p2 = model.init_params(CFG, jnp.uint32(42), scalar_head=False)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)
    p3 = model.init_params(CFG, jnp.uint32(43), scalar_head=False)
    assert any(
        not np.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3))
    )


def test_generate_rollout_contract():
    """Fused rollout artifact: prompt preserved, PAD after EOS, tokens in
    vocab, seed-deterministic."""
    import jax
    cfg = CFG_JNP
    params = model.init_params(cfg, jnp.uint32(0), scalar_head=False)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (cfg.batch, cfg.prompt_len), 32, 127
    )
    rows = model.generate_rollout(
        cfg, params, prompts, jnp.uint32(7), jnp.float32(0.8)
    )
    assert rows.shape == (cfg.batch, cfg.max_seq)
    assert bool((rows[:, : cfg.prompt_len] == prompts).all())
    assert bool(((rows >= 0) & (rows < cfg.vocab)).all())
    # after the first EOS in the generated span, everything is PAD
    import numpy as np
    r = np.asarray(rows)
    for row in r:
        gen = row[cfg.prompt_len:]
        eos = np.where(gen == 10)[0]
        if len(eos):
            assert (gen[eos[0] + 1:] == 0).all()
    # determinism given the seed
    rows2 = model.generate_rollout(
        cfg, params, prompts, jnp.uint32(7), jnp.float32(0.8)
    )
    assert bool((rows == rows2).all())
    rows3 = model.generate_rollout(
        cfg, params, prompts, jnp.uint32(8), jnp.float32(0.8)
    )
    assert not bool((rows == rows3).all())
